//! Ingress metrics for the network serving tier: per-connection and
//! per-model row accounting, folded into
//! [`FleetSnapshot`](crate::coordinator::metrics::FleetSnapshot) when a socket
//! listener fronted the registry.
//!
//! The net tier extends the pipeline's exact accounting invariant to
//! the wire: every row that arrives in a well-formed request frame is
//! answered exactly once, either with a per-row verdict (ok / queue-
//! full / deadline / panicked / shutdown) or covered by a frame-level
//! typed error (unknown model, admission rejected). Per model,
//!
//! ```text
//! rows_admitted == rows_ok + rows_queue_full + rows_deadline_shed
//!                + rows_panicked + rows_shutdown
//! ```
//!
//! and admission-rejected / rate-limited rows are counted separately
//! (they never entered a pipeline). [`NetSnapshot::assert_accounted`]
//! checks the invariant for every model.
//!
//! **Swap-aware latency.** Wire latency is additionally recorded per
//! `(model, artifact version)` — the version each row's verdict came
//! back stamped with — so a canary that passes its quarantine batch
//! but serves slow is visible as a distinct sub-histogram next to the
//! incumbent's within one swap interval, instead of being averaged
//! into the model's aggregate.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::admission::AdmissionSnapshot;
use super::proto::Status;
use crate::util::percentile;

/// Cap on retained per-(model, version) latency samples; recording
/// stops at the cap (percentiles then describe the first N rows).
const MAX_VERSION_SAMPLES: usize = 50_000;

/// Per-connection counters reported after the connection closes.
/// Bounded: only the first [`MAX_CONNS_TRACKED`] closed connections
/// keep their individual entry (totals always cover everything).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ConnIngress {
    /// Server-assigned connection id (monotonic per reactor set).
    pub id: u64,
    /// Peer address at accept time.
    pub peer: String,
    /// Request frames received.
    pub frames_in: u64,
    /// Reply/error/goaway frames written to this connection.
    pub frames_out: u64,
    /// Rows received in well-formed request frames.
    pub rows_in: u64,
    /// Raw bytes read.
    pub bytes_in: u64,
    /// Raw bytes written.
    pub bytes_out: u64,
    /// True if the connection was failed closed on a protocol error.
    pub protocol_error: bool,
}

/// Cap on individually-retained closed-connection entries.
pub const MAX_CONNS_TRACKED: usize = 256;

/// Per-model row outcome counters at the wire boundary.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ModelIngress {
    /// Rows that passed admission and were submitted to the pipeline.
    pub rows_admitted: u64,
    /// Rows answered with logits.
    pub rows_ok: u64,
    /// Rows shed by the per-model queue (pipeline backpressure).
    pub rows_queue_full: u64,
    /// Rows shed by the pipeline deadline.
    pub rows_deadline_shed: u64,
    /// Rows failed by a worker panic.
    pub rows_panicked: u64,
    /// Rows refused because the pipeline was draining.
    pub rows_shutdown: u64,
    /// Rows refused by the shared admission budget (never submitted).
    pub rows_admission_rejected: u64,
    /// Rows refused by a per-connection rate limit (never submitted).
    pub rows_rate_limited: u64,
}

impl ModelIngress {
    /// True iff every admitted row has exactly one recorded verdict.
    pub fn accounted(&self) -> bool {
        self.rows_admitted
            == self.rows_ok
                + self.rows_queue_full
                + self.rows_deadline_shed
                + self.rows_panicked
                + self.rows_shutdown
    }

    /// All rows this model saw at the wire, shed or served.
    pub fn rows_total(&self) -> u64 {
        self.rows_admitted + self.rows_admission_rejected + self.rows_rate_limited
    }
}

/// Wire-latency distribution of one `(model, artifact version)` pair.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct WireVersionStats {
    /// Ok rows served by this version.
    pub rows: u64,
    /// Median wire latency (request frame in → reply queued), µs.
    pub p50_us: f64,
    /// p99 wire latency, µs.
    pub p99_us: f64,
}

#[derive(Debug, Default)]
struct VersionAgg {
    rows: u64,
    lat_us: Vec<f64>,
}

#[derive(Debug, Default)]
struct ModelCells {
    admitted: AtomicU64,
    ok: AtomicU64,
    queue_full: AtomicU64,
    deadline_shed: AtomicU64,
    panicked: AtomicU64,
    shutdown: AtomicU64,
    admission_rejected: AtomicU64,
    rate_limited: AtomicU64,
    versions: Mutex<BTreeMap<u64, VersionAgg>>,
}

/// Live counters shared by every reactor and dispatcher thread.
#[derive(Debug, Default)]
pub struct NetMetrics {
    accepted: AtomicU64,
    closed: AtomicU64,
    bytes_in: AtomicU64,
    bytes_out: AtomicU64,
    frames_in: AtomicU64,
    frames_out: AtomicU64,
    protocol_errors: AtomicU64,
    unknown_model_frames: AtomicU64,
    auth_failures: AtomicU64,
    connections_refused: AtomicU64,
    goaways_sent: AtomicU64,
    frames_replayed: AtomicU64,
    rows_replayed: AtomicU64,
    rows_done: AtomicU64,
    models: Mutex<BTreeMap<String, Arc<ModelCells>>>,
    conns: Mutex<Vec<ConnIngress>>,
}

impl NetMetrics {
    /// Fresh zeroed metrics.
    pub fn new() -> Arc<NetMetrics> {
        Arc::new(NetMetrics::default())
    }

    fn model(&self, name: &str) -> Arc<ModelCells> {
        let mut models = self.models.lock().unwrap_or_else(|e| e.into_inner());
        models.entry(name.to_string()).or_default().clone()
    }

    /// A connection was accepted.
    pub fn record_accept(&self) {
        self.accepted.fetch_add(1, Ordering::Relaxed);
    }

    /// A connection closed; retain its counters (bounded).
    pub fn record_close(&self, conn: ConnIngress) {
        self.closed.fetch_add(1, Ordering::Relaxed);
        let mut conns = self.conns.lock().unwrap_or_else(|e| e.into_inner());
        if conns.len() < MAX_CONNS_TRACKED {
            conns.push(conn);
        }
    }

    /// Raw bytes read off a socket.
    pub fn record_bytes_in(&self, n: u64) {
        self.bytes_in.fetch_add(n, Ordering::Relaxed);
    }

    /// Raw bytes written to a socket.
    pub fn record_bytes_out(&self, n: u64) {
        self.bytes_out.fetch_add(n, Ordering::Relaxed);
    }

    /// A well-formed request frame arrived.
    pub fn record_frame_in(&self) {
        self.frames_in.fetch_add(1, Ordering::Relaxed);
    }

    /// A reply or error frame was queued for write.
    pub fn record_frame_out(&self) {
        self.frames_out.fetch_add(1, Ordering::Relaxed);
    }

    /// A frame violated the protocol (connection fails closed).
    pub fn record_protocol_error(&self) {
        self.protocol_errors.fetch_add(1, Ordering::Relaxed);
    }

    /// A request frame named an unregistered model (`rows` covered by
    /// the error frame).
    pub fn record_unknown_model(&self, rows: u64) {
        self.unknown_model_frames.fetch_add(1, Ordering::Relaxed);
        self.rows_done.fetch_add(rows, Ordering::Relaxed);
    }

    /// A request frame arrived while the server was draining and was
    /// answered with a `ShuttingDown` error frame.
    pub fn record_drain_refused(&self, rows: u64) {
        self.rows_done.fetch_add(rows, Ordering::Relaxed);
    }

    /// A frame was refused by the shared admission budget.
    pub fn record_admission_rejected(&self, model: &str, rows: u64) {
        self.model(model).admission_rejected.fetch_add(rows, Ordering::Relaxed);
        self.rows_done.fetch_add(rows, Ordering::Relaxed);
    }

    /// A frame was refused by a per-connection frame/row rate limit.
    pub fn record_rate_limited(&self, model: &str, rows: u64) {
        self.model(model).rate_limited.fetch_add(rows, Ordering::Relaxed);
        self.rows_done.fetch_add(rows, Ordering::Relaxed);
    }

    /// A connection failed authentication (missing/wrong token before
    /// the first request); it is failed closed.
    pub fn record_auth_failure(&self) {
        self.auth_failures.fetch_add(1, Ordering::Relaxed);
    }

    /// A connection was refused by the max-connections cap.
    pub fn record_conn_refused(&self) {
        self.connections_refused.fetch_add(1, Ordering::Relaxed);
    }

    /// A `GoAway` drain notice was queued on a connection.
    pub fn record_goaway(&self) {
        self.goaways_sent.fetch_add(1, Ordering::Relaxed);
    }

    /// A keyed request was answered from the replay cache instead of
    /// being re-submitted (`rows` rows covered by the cached reply).
    /// Replays are deliberately NOT part of `rows_done`: they answer a
    /// row the ledger already counted once.
    pub fn record_replay(&self, rows: u64) {
        self.frames_replayed.fetch_add(1, Ordering::Relaxed);
        self.rows_replayed.fetch_add(rows, Ordering::Relaxed);
    }

    /// One Ok row's wire latency, attributed to the artifact version
    /// that served it.
    pub fn record_version_latency(&self, model: &str, version: u64, us: f64) {
        let cells = self.model(model);
        let mut versions = cells.versions.lock().unwrap_or_else(|e| e.into_inner());
        let agg = versions.entry(version).or_default();
        agg.rows += 1;
        if agg.lat_us.len() < MAX_VERSION_SAMPLES {
            agg.lat_us.push(us);
        }
    }

    /// `rows` rows were submitted into `model`'s pipeline.
    pub fn record_admitted(&self, model: &str, rows: u64) {
        self.model(model).admitted.fetch_add(rows, Ordering::Relaxed);
    }

    /// One row's pipeline verdict came back.
    pub fn record_row_verdict(&self, model: &str, status: Status) {
        let cells = self.model(model);
        let cell = match status {
            Status::Ok => &cells.ok,
            Status::QueueFull => &cells.queue_full,
            Status::DeadlineExceeded => &cells.deadline_shed,
            Status::WorkerPanicked => &cells.panicked,
            // anything else the dispatcher maps onto a row is a drain
            Status::ShutDown
            | Status::UnknownModel
            | Status::AdmissionRejected
            | Status::Malformed
            | Status::AuthFailed
            | Status::RateLimited
            | Status::TooManyConnections => &cells.shutdown,
        };
        cell.fetch_add(1, Ordering::Relaxed);
        self.rows_done.fetch_add(1, Ordering::Relaxed);
    }

    /// Total rows answered over the wire (verdicts + frame-level
    /// errors). This is the serve loop's progress/termination counter.
    pub fn rows_done(&self) -> u64 {
        self.rows_done.load(Ordering::Relaxed)
    }

    /// Freeze every counter. `admission` is attached verbatim.
    pub fn snapshot(&self, admission: AdmissionSnapshot) -> NetSnapshot {
        let models = self.models.lock().unwrap_or_else(|e| e.into_inner());
        let conns = self.conns.lock().unwrap_or_else(|e| e.into_inner());
        NetSnapshot {
            connections_accepted: self.accepted.load(Ordering::Relaxed),
            connections_closed: self.closed.load(Ordering::Relaxed),
            bytes_in: self.bytes_in.load(Ordering::Relaxed),
            bytes_out: self.bytes_out.load(Ordering::Relaxed),
            frames_in: self.frames_in.load(Ordering::Relaxed),
            frames_out: self.frames_out.load(Ordering::Relaxed),
            protocol_errors: self.protocol_errors.load(Ordering::Relaxed),
            unknown_model_frames: self.unknown_model_frames.load(Ordering::Relaxed),
            auth_failures: self.auth_failures.load(Ordering::Relaxed),
            connections_refused: self.connections_refused.load(Ordering::Relaxed),
            goaways_sent: self.goaways_sent.load(Ordering::Relaxed),
            frames_replayed: self.frames_replayed.load(Ordering::Relaxed),
            rows_replayed: self.rows_replayed.load(Ordering::Relaxed),
            rows_done: self.rows_done.load(Ordering::Relaxed),
            models: models
                .iter()
                .map(|(name, c)| {
                    (
                        name.clone(),
                        ModelIngress {
                            rows_admitted: c.admitted.load(Ordering::Relaxed),
                            rows_ok: c.ok.load(Ordering::Relaxed),
                            rows_queue_full: c.queue_full.load(Ordering::Relaxed),
                            rows_deadline_shed: c.deadline_shed.load(Ordering::Relaxed),
                            rows_panicked: c.panicked.load(Ordering::Relaxed),
                            rows_shutdown: c.shutdown.load(Ordering::Relaxed),
                            rows_admission_rejected: c
                                .admission_rejected
                                .load(Ordering::Relaxed),
                            rows_rate_limited: c.rate_limited.load(Ordering::Relaxed),
                        },
                    )
                })
                .collect(),
            versions: models
                .iter()
                .map(|(name, c)| {
                    let versions = c.versions.lock().unwrap_or_else(|e| e.into_inner());
                    (
                        name.clone(),
                        versions
                            .iter()
                            .map(|(v, agg)| {
                                (
                                    *v,
                                    WireVersionStats {
                                        rows: agg.rows,
                                        p50_us: percentile(&agg.lat_us, 50.0),
                                        p99_us: percentile(&agg.lat_us, 99.0),
                                    },
                                )
                            })
                            .collect(),
                    )
                })
                .collect(),
            connections: conns.clone(),
            admission,
        }
    }
}

/// Frozen ingress state of the whole net tier.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct NetSnapshot {
    /// Connections accepted over the run.
    pub connections_accepted: u64,
    /// Connections closed over the run.
    pub connections_closed: u64,
    /// Raw bytes read.
    pub bytes_in: u64,
    /// Raw bytes written.
    pub bytes_out: u64,
    /// Well-formed request frames received.
    pub frames_in: u64,
    /// Reply/error frames sent.
    pub frames_out: u64,
    /// Frames that violated the protocol (each fails a connection).
    pub protocol_errors: u64,
    /// Request frames naming an unregistered model.
    pub unknown_model_frames: u64,
    /// Connections failed closed on a missing/wrong auth token.
    pub auth_failures: u64,
    /// Connections refused by the max-connections cap.
    pub connections_refused: u64,
    /// `GoAway` drain notices sent.
    pub goaways_sent: u64,
    /// Keyed request frames answered from the replay cache.
    pub frames_replayed: u64,
    /// Rows covered by replayed reply frames (not in `rows_done`).
    pub rows_replayed: u64,
    /// Total rows answered over the wire.
    pub rows_done: u64,
    /// Per-model wire-boundary row accounting.
    pub models: BTreeMap<String, ModelIngress>,
    /// Per-model, per-artifact-version wire latency sub-histograms
    /// (Ok rows only).
    pub versions: BTreeMap<String, BTreeMap<u64, WireVersionStats>>,
    /// Individually-retained closed connections (bounded by
    /// [`MAX_CONNS_TRACKED`]).
    pub connections: Vec<ConnIngress>,
    /// Shared admission-controller state.
    pub admission: AdmissionSnapshot,
}

impl NetSnapshot {
    /// Panic if any model's wire accounting does not balance exactly.
    pub fn assert_accounted(&self) {
        for (name, m) in &self.models {
            assert!(
                m.accounted(),
                "net ingress accounting broken for '{name}': {m:?}"
            );
        }
    }

    /// Rows served with logits, across models.
    pub fn rows_ok(&self) -> u64 {
        self.models.values().map(|m| m.rows_ok).sum()
    }

    /// Rows refused by the shared admission budget, across models.
    pub fn rows_admission_rejected(&self) -> u64 {
        self.models.values().map(|m| m.rows_admission_rejected).sum()
    }
}

impl std::fmt::Display for NetSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "net: {} conns ({} closed, {} refused) | frames {} in / {} out \
             ({} replayed) | {} B in / {} B out | {} protocol errors, \
             {} unknown-model frames, {} auth failures, {} goaways",
            self.connections_accepted,
            self.connections_closed,
            self.connections_refused,
            self.frames_in,
            self.frames_out,
            self.frames_replayed,
            self.bytes_in,
            self.bytes_out,
            self.protocol_errors,
            self.unknown_model_frames,
            self.auth_failures,
            self.goaways_sent,
        )?;
        for (name, m) in &self.models {
            writeln!(
                f,
                "net[{name}]: {} admitted = {} ok + {} queue-full + {} deadline + \
                 {} panicked + {} shutdown | {} admission-rejected, {} rate-limited",
                m.rows_admitted,
                m.rows_ok,
                m.rows_queue_full,
                m.rows_deadline_shed,
                m.rows_panicked,
                m.rows_shutdown,
                m.rows_admission_rejected,
                m.rows_rate_limited,
            )?;
            if let Some(versions) = self.versions.get(name) {
                for (v, stats) in versions {
                    writeln!(
                        f,
                        "net[{name}] v{v}: {} ok rows, wire p50 {:.0}µs p99 {:.0}µs",
                        stats.rows, stats.p50_us, stats.p99_us
                    )?;
                }
            }
        }
        write!(f, "{}", self.admission)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accounting_invariant_checks_per_model() {
        let m = NetMetrics::new();
        m.record_admitted("a", 10);
        for _ in 0..7 {
            m.record_row_verdict("a", Status::Ok);
        }
        m.record_row_verdict("a", Status::QueueFull);
        m.record_row_verdict("a", Status::DeadlineExceeded);
        let snap = m.snapshot(AdmissionSnapshot::default());
        assert!(!snap.models["a"].accounted(), "one row still unaccounted");
        m.record_row_verdict("a", Status::WorkerPanicked);
        let snap = m.snapshot(AdmissionSnapshot::default());
        snap.assert_accounted();
        assert_eq!(snap.rows_done, 10);
        assert_eq!(snap.rows_ok(), 7);
    }

    #[test]
    fn frame_level_errors_count_toward_rows_done_not_admitted() {
        let m = NetMetrics::new();
        m.record_unknown_model(16);
        m.record_admission_rejected("a", 32);
        let snap = m.snapshot(AdmissionSnapshot::default());
        snap.assert_accounted();
        assert_eq!(snap.rows_done, 48);
        assert_eq!(snap.unknown_model_frames, 1);
        assert_eq!(snap.models["a"].rows_admission_rejected, 32);
        assert_eq!(snap.rows_admission_rejected(), 32);
    }

    #[test]
    fn hardening_rejections_are_typed_counted_and_ledger_safe() {
        let m = NetMetrics::new();
        m.record_rate_limited("a", 8);
        m.record_auth_failure();
        m.record_conn_refused();
        m.record_goaway();
        m.record_replay(5);
        let snap = m.snapshot(AdmissionSnapshot::default());
        snap.assert_accounted();
        assert_eq!(snap.models["a"].rows_rate_limited, 8);
        assert_eq!(snap.models["a"].rows_total(), 8);
        assert_eq!(snap.rows_done, 8, "rate-limited rows are still answered rows");
        assert_eq!(snap.auth_failures, 1);
        assert_eq!(snap.connections_refused, 1);
        assert_eq!(snap.goaways_sent, 1);
        assert_eq!((snap.frames_replayed, snap.rows_replayed), (1, 5));
        assert_eq!(snap.rows_done, 8, "replays never double-count the ledger");
    }

    #[test]
    fn per_version_latency_histograms_stay_distinct() {
        let m = NetMetrics::new();
        // v1 serves fast, v2 (the slow canary) 10x slower; the split
        // must survive into the snapshot instead of averaging away
        for _ in 0..100 {
            m.record_version_latency("digits", 1, 100.0);
            m.record_version_latency("digits", 2, 1000.0);
        }
        let snap = m.snapshot(AdmissionSnapshot::default());
        let v = &snap.versions["digits"];
        assert_eq!(v[&1].rows, 100);
        assert_eq!(v[&2].rows, 100);
        assert_eq!(v[&1].p50_us, 100.0);
        assert_eq!(v[&2].p50_us, 1000.0);
        assert!(v[&2].p99_us >= 10.0 * v[&1].p99_us * 0.99);
        let text = format!("{snap}");
        assert!(text.contains("net[digits] v1:"), "{text}");
        assert!(text.contains("net[digits] v2:"), "{text}");
    }

    #[test]
    fn closed_connection_entries_are_bounded() {
        let m = NetMetrics::new();
        for id in 0..(MAX_CONNS_TRACKED as u64 + 50) {
            m.record_accept();
            m.record_close(ConnIngress { id, ..ConnIngress::default() });
        }
        let snap = m.snapshot(AdmissionSnapshot::default());
        assert_eq!(snap.connections_closed, MAX_CONNS_TRACKED as u64 + 50);
        assert_eq!(snap.connections.len(), MAX_CONNS_TRACKED);
    }

    #[test]
    fn display_is_single_pass_and_total() {
        let m = NetMetrics::new();
        m.record_admitted("digits", 4);
        for _ in 0..4 {
            m.record_row_verdict("digits", Status::Ok);
        }
        let snap = m.snapshot(AdmissionSnapshot::default());
        let text = format!("{snap}");
        assert!(text.contains("net[digits]: 4 admitted = 4 ok"));
        assert!(text.contains("admission: unlimited"));
    }
}
