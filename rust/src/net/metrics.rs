//! Ingress metrics for the network serving tier: per-connection and
//! per-model row accounting, folded into
//! [`FleetSnapshot`](crate::coordinator::FleetSnapshot) when a socket
//! listener fronted the registry.
//!
//! The net tier extends the pipeline's exact accounting invariant to
//! the wire: every row that arrives in a well-formed request frame is
//! answered exactly once, either with a per-row verdict (ok / queue-
//! full / deadline / panicked / shutdown) or covered by a frame-level
//! typed error (unknown model, admission rejected). Per model,
//!
//! ```text
//! rows_admitted == rows_ok + rows_queue_full + rows_deadline_shed
//!                + rows_panicked + rows_shutdown
//! ```
//!
//! and admission-rejected rows are counted separately (they never
//! entered a pipeline). [`NetSnapshot::assert_accounted`] checks the
//! invariant for every model.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::admission::AdmissionSnapshot;
use super::proto::Status;

/// Per-connection counters reported after the connection closes.
/// Bounded: only the first [`MAX_CONNS_TRACKED`] closed connections
/// keep their individual entry (totals always cover everything).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ConnIngress {
    /// Server-assigned connection id (monotonic per reactor set).
    pub id: u64,
    /// Peer address at accept time.
    pub peer: String,
    /// Request frames received.
    pub frames_in: u64,
    /// Rows received in well-formed request frames.
    pub rows_in: u64,
    /// Raw bytes read.
    pub bytes_in: u64,
    /// Raw bytes written.
    pub bytes_out: u64,
    /// True if the connection was failed closed on a protocol error.
    pub protocol_error: bool,
}

/// Cap on individually-retained closed-connection entries.
pub const MAX_CONNS_TRACKED: usize = 256;

/// Per-model row outcome counters at the wire boundary.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ModelIngress {
    /// Rows that passed admission and were submitted to the pipeline.
    pub rows_admitted: u64,
    /// Rows answered with logits.
    pub rows_ok: u64,
    /// Rows shed by the per-model queue (pipeline backpressure).
    pub rows_queue_full: u64,
    /// Rows shed by the pipeline deadline.
    pub rows_deadline_shed: u64,
    /// Rows failed by a worker panic.
    pub rows_panicked: u64,
    /// Rows refused because the pipeline was draining.
    pub rows_shutdown: u64,
    /// Rows refused by the shared admission budget (never submitted).
    pub rows_admission_rejected: u64,
}

impl ModelIngress {
    /// True iff every admitted row has exactly one recorded verdict.
    pub fn accounted(&self) -> bool {
        self.rows_admitted
            == self.rows_ok
                + self.rows_queue_full
                + self.rows_deadline_shed
                + self.rows_panicked
                + self.rows_shutdown
    }

    /// All rows this model saw at the wire, shed or served.
    pub fn rows_total(&self) -> u64 {
        self.rows_admitted + self.rows_admission_rejected
    }
}

#[derive(Debug, Default)]
struct ModelCells {
    admitted: AtomicU64,
    ok: AtomicU64,
    queue_full: AtomicU64,
    deadline_shed: AtomicU64,
    panicked: AtomicU64,
    shutdown: AtomicU64,
    admission_rejected: AtomicU64,
}

/// Live counters shared by every reactor and dispatcher thread.
#[derive(Debug, Default)]
pub struct NetMetrics {
    accepted: AtomicU64,
    closed: AtomicU64,
    bytes_in: AtomicU64,
    bytes_out: AtomicU64,
    frames_in: AtomicU64,
    frames_out: AtomicU64,
    protocol_errors: AtomicU64,
    unknown_model_frames: AtomicU64,
    rows_done: AtomicU64,
    models: Mutex<BTreeMap<String, Arc<ModelCells>>>,
    conns: Mutex<Vec<ConnIngress>>,
}

impl NetMetrics {
    /// Fresh zeroed metrics.
    pub fn new() -> Arc<NetMetrics> {
        Arc::new(NetMetrics::default())
    }

    fn model(&self, name: &str) -> Arc<ModelCells> {
        let mut models = self.models.lock().unwrap_or_else(|e| e.into_inner());
        models.entry(name.to_string()).or_default().clone()
    }

    /// A connection was accepted.
    pub fn record_accept(&self) {
        self.accepted.fetch_add(1, Ordering::Relaxed);
    }

    /// A connection closed; retain its counters (bounded).
    pub fn record_close(&self, conn: ConnIngress) {
        self.closed.fetch_add(1, Ordering::Relaxed);
        let mut conns = self.conns.lock().unwrap_or_else(|e| e.into_inner());
        if conns.len() < MAX_CONNS_TRACKED {
            conns.push(conn);
        }
    }

    /// Raw bytes read off a socket.
    pub fn record_bytes_in(&self, n: u64) {
        self.bytes_in.fetch_add(n, Ordering::Relaxed);
    }

    /// Raw bytes written to a socket.
    pub fn record_bytes_out(&self, n: u64) {
        self.bytes_out.fetch_add(n, Ordering::Relaxed);
    }

    /// A well-formed request frame arrived.
    pub fn record_frame_in(&self) {
        self.frames_in.fetch_add(1, Ordering::Relaxed);
    }

    /// A reply or error frame was queued for write.
    pub fn record_frame_out(&self) {
        self.frames_out.fetch_add(1, Ordering::Relaxed);
    }

    /// A frame violated the protocol (connection fails closed).
    pub fn record_protocol_error(&self) {
        self.protocol_errors.fetch_add(1, Ordering::Relaxed);
    }

    /// A request frame named an unregistered model (`rows` covered by
    /// the error frame).
    pub fn record_unknown_model(&self, rows: u64) {
        self.unknown_model_frames.fetch_add(1, Ordering::Relaxed);
        self.rows_done.fetch_add(rows, Ordering::Relaxed);
    }

    /// A request frame arrived while the server was draining and was
    /// answered with a `ShuttingDown` error frame.
    pub fn record_drain_refused(&self, rows: u64) {
        self.rows_done.fetch_add(rows, Ordering::Relaxed);
    }

    /// A frame was refused by the shared admission budget.
    pub fn record_admission_rejected(&self, model: &str, rows: u64) {
        self.model(model).admission_rejected.fetch_add(rows, Ordering::Relaxed);
        self.rows_done.fetch_add(rows, Ordering::Relaxed);
    }

    /// `rows` rows were submitted into `model`'s pipeline.
    pub fn record_admitted(&self, model: &str, rows: u64) {
        self.model(model).admitted.fetch_add(rows, Ordering::Relaxed);
    }

    /// One row's pipeline verdict came back.
    pub fn record_row_verdict(&self, model: &str, status: Status) {
        let cells = self.model(model);
        let cell = match status {
            Status::Ok => &cells.ok,
            Status::QueueFull => &cells.queue_full,
            Status::DeadlineExceeded => &cells.deadline_shed,
            Status::WorkerPanicked => &cells.panicked,
            // anything else the dispatcher maps onto a row is a drain
            Status::ShutDown
            | Status::UnknownModel
            | Status::AdmissionRejected
            | Status::Malformed => &cells.shutdown,
        };
        cell.fetch_add(1, Ordering::Relaxed);
        self.rows_done.fetch_add(1, Ordering::Relaxed);
    }

    /// Total rows answered over the wire (verdicts + frame-level
    /// errors). This is the serve loop's progress/termination counter.
    pub fn rows_done(&self) -> u64 {
        self.rows_done.load(Ordering::Relaxed)
    }

    /// Freeze every counter. `admission` is attached verbatim.
    pub fn snapshot(&self, admission: AdmissionSnapshot) -> NetSnapshot {
        let models = self.models.lock().unwrap_or_else(|e| e.into_inner());
        let conns = self.conns.lock().unwrap_or_else(|e| e.into_inner());
        NetSnapshot {
            connections_accepted: self.accepted.load(Ordering::Relaxed),
            connections_closed: self.closed.load(Ordering::Relaxed),
            bytes_in: self.bytes_in.load(Ordering::Relaxed),
            bytes_out: self.bytes_out.load(Ordering::Relaxed),
            frames_in: self.frames_in.load(Ordering::Relaxed),
            frames_out: self.frames_out.load(Ordering::Relaxed),
            protocol_errors: self.protocol_errors.load(Ordering::Relaxed),
            unknown_model_frames: self.unknown_model_frames.load(Ordering::Relaxed),
            rows_done: self.rows_done.load(Ordering::Relaxed),
            models: models
                .iter()
                .map(|(name, c)| {
                    (
                        name.clone(),
                        ModelIngress {
                            rows_admitted: c.admitted.load(Ordering::Relaxed),
                            rows_ok: c.ok.load(Ordering::Relaxed),
                            rows_queue_full: c.queue_full.load(Ordering::Relaxed),
                            rows_deadline_shed: c.deadline_shed.load(Ordering::Relaxed),
                            rows_panicked: c.panicked.load(Ordering::Relaxed),
                            rows_shutdown: c.shutdown.load(Ordering::Relaxed),
                            rows_admission_rejected: c
                                .admission_rejected
                                .load(Ordering::Relaxed),
                        },
                    )
                })
                .collect(),
            connections: conns.clone(),
            admission,
        }
    }
}

/// Frozen ingress state of the whole net tier.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct NetSnapshot {
    /// Connections accepted over the run.
    pub connections_accepted: u64,
    /// Connections closed over the run.
    pub connections_closed: u64,
    /// Raw bytes read.
    pub bytes_in: u64,
    /// Raw bytes written.
    pub bytes_out: u64,
    /// Well-formed request frames received.
    pub frames_in: u64,
    /// Reply/error frames sent.
    pub frames_out: u64,
    /// Frames that violated the protocol (each fails a connection).
    pub protocol_errors: u64,
    /// Request frames naming an unregistered model.
    pub unknown_model_frames: u64,
    /// Total rows answered over the wire.
    pub rows_done: u64,
    /// Per-model wire-boundary row accounting.
    pub models: BTreeMap<String, ModelIngress>,
    /// Individually-retained closed connections (bounded by
    /// [`MAX_CONNS_TRACKED`]).
    pub connections: Vec<ConnIngress>,
    /// Shared admission-controller state.
    pub admission: AdmissionSnapshot,
}

impl NetSnapshot {
    /// Panic if any model's wire accounting does not balance exactly.
    pub fn assert_accounted(&self) {
        for (name, m) in &self.models {
            assert!(
                m.accounted(),
                "net ingress accounting broken for '{name}': {m:?}"
            );
        }
    }

    /// Rows served with logits, across models.
    pub fn rows_ok(&self) -> u64 {
        self.models.values().map(|m| m.rows_ok).sum()
    }

    /// Rows refused by the shared admission budget, across models.
    pub fn rows_admission_rejected(&self) -> u64 {
        self.models.values().map(|m| m.rows_admission_rejected).sum()
    }
}

impl std::fmt::Display for NetSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "net: {} conns ({} closed) | frames {} in / {} out | {} B in / {} B out | \
             {} protocol errors, {} unknown-model frames",
            self.connections_accepted,
            self.connections_closed,
            self.frames_in,
            self.frames_out,
            self.bytes_in,
            self.bytes_out,
            self.protocol_errors,
            self.unknown_model_frames,
        )?;
        for (name, m) in &self.models {
            writeln!(
                f,
                "net[{name}]: {} admitted = {} ok + {} queue-full + {} deadline + \
                 {} panicked + {} shutdown | {} admission-rejected",
                m.rows_admitted,
                m.rows_ok,
                m.rows_queue_full,
                m.rows_deadline_shed,
                m.rows_panicked,
                m.rows_shutdown,
                m.rows_admission_rejected,
            )?;
        }
        write!(f, "{}", self.admission)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accounting_invariant_checks_per_model() {
        let m = NetMetrics::new();
        m.record_admitted("a", 10);
        for _ in 0..7 {
            m.record_row_verdict("a", Status::Ok);
        }
        m.record_row_verdict("a", Status::QueueFull);
        m.record_row_verdict("a", Status::DeadlineExceeded);
        let snap = m.snapshot(AdmissionSnapshot::default());
        assert!(!snap.models["a"].accounted(), "one row still unaccounted");
        m.record_row_verdict("a", Status::WorkerPanicked);
        let snap = m.snapshot(AdmissionSnapshot::default());
        snap.assert_accounted();
        assert_eq!(snap.rows_done, 10);
        assert_eq!(snap.rows_ok(), 7);
    }

    #[test]
    fn frame_level_errors_count_toward_rows_done_not_admitted() {
        let m = NetMetrics::new();
        m.record_unknown_model(16);
        m.record_admission_rejected("a", 32);
        let snap = m.snapshot(AdmissionSnapshot::default());
        snap.assert_accounted();
        assert_eq!(snap.rows_done, 48);
        assert_eq!(snap.unknown_model_frames, 1);
        assert_eq!(snap.models["a"].rows_admission_rejected, 32);
        assert_eq!(snap.rows_admission_rejected(), 32);
    }

    #[test]
    fn closed_connection_entries_are_bounded() {
        let m = NetMetrics::new();
        for id in 0..(MAX_CONNS_TRACKED as u64 + 50) {
            m.record_accept();
            m.record_close(ConnIngress { id, ..ConnIngress::default() });
        }
        let snap = m.snapshot(AdmissionSnapshot::default());
        assert_eq!(snap.connections_closed, MAX_CONNS_TRACKED as u64 + 50);
        assert_eq!(snap.connections.len(), MAX_CONNS_TRACKED);
    }

    #[test]
    fn display_is_single_pass_and_total() {
        let m = NetMetrics::new();
        m.record_admitted("digits", 4);
        for _ in 0..4 {
            m.record_row_verdict("digits", Status::Ok);
        }
        let snap = m.snapshot(AdmissionSnapshot::default());
        let text = format!("{snap}");
        assert!(text.contains("net[digits]: 4 admitted = 4 ok"));
        assert!(text.contains("admission: unlimited"));
    }
}
