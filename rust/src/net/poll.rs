//! Readiness polling over a minimal raw-syscall FFI shim — epoll on
//! Linux, kqueue on macOS — in the same spirit as the `mmap` shim in
//! `bytes.rs`: no `libc`/`mio`/`tokio`, just the two or three syscalls
//! the reactor actually needs, declared `extern "C"` and wrapped in a
//! safe [`Poller`] handle.
//!
//! The poller is level-triggered: an fd with buffered input keeps
//! reporting readable until drained, which keeps reactor logic simple
//! (no starvation bookkeeping on short reads). On unix platforms
//! without a backend here, [`Poller::new`] returns `Unsupported` and
//! the serving tier refuses to start — the rest of the crate is
//! unaffected.

use std::io;
use std::os::unix::io::RawFd;

/// One readiness event delivered by [`Poller::wait`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Event {
    /// Caller-chosen token registered with the fd.
    pub token: u64,
    /// Reading will not block (data buffered, or EOF/err pending).
    pub readable: bool,
    /// Writing will not block.
    pub writable: bool,
    /// Peer hung up or the fd is in an error state.
    pub closed: bool,
}

/// A readiness-poll instance (one per reactor thread).
#[derive(Debug)]
pub struct Poller {
    imp: imp::Poller,
}

impl Poller {
    /// A fresh poll instance, or `Unsupported` where no backend exists.
    pub fn new() -> io::Result<Poller> {
        Ok(Poller { imp: imp::Poller::new()? })
    }

    /// Register `fd` under `token` with the given interest set.
    pub fn add(&self, fd: RawFd, token: u64, readable: bool, writable: bool) -> io::Result<()> {
        self.imp.ctl(imp::Op::Add, fd, token, readable, writable)
    }

    /// Replace the interest set of an already-registered `fd`.
    pub fn modify(
        &self,
        fd: RawFd,
        token: u64,
        readable: bool,
        writable: bool,
    ) -> io::Result<()> {
        self.imp.ctl(imp::Op::Modify, fd, token, readable, writable)
    }

    /// Deregister `fd`.
    pub fn delete(&self, fd: RawFd) -> io::Result<()> {
        self.imp.ctl(imp::Op::Delete, fd, 0, false, false)
    }

    /// Block up to `timeout_ms` (-1 = forever) and append ready events
    /// to `out`. Returns the number of events appended; `0` on timeout.
    pub fn wait(&self, out: &mut Vec<Event>, timeout_ms: i32) -> io::Result<usize> {
        self.imp.wait(out, timeout_ms)
    }
}

#[cfg(any(target_os = "linux", target_os = "android"))]
mod imp {
    use super::Event;
    use std::io;
    use std::os::unix::io::RawFd;

    // mirror of the kernel's struct epoll_event; packed on x86-64 only,
    // matching the kernel ABI
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    extern "C" {
        fn epoll_create1(flags: i32) -> i32;
        fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32) -> i32;
        fn close(fd: i32) -> i32;
    }

    const EPOLL_CLOEXEC: i32 = 0o2000000;
    const EPOLL_CTL_ADD: i32 = 1;
    const EPOLL_CTL_DEL: i32 = 2;
    const EPOLL_CTL_MOD: i32 = 3;
    const EPOLLIN: u32 = 0x001;
    const EPOLLOUT: u32 = 0x004;
    const EPOLLERR: u32 = 0x008;
    const EPOLLHUP: u32 = 0x010;
    const EPOLLRDHUP: u32 = 0x2000;

    #[derive(Debug)]
    pub(super) struct Poller {
        epfd: RawFd,
    }

    pub(super) enum Op {
        Add,
        Modify,
        Delete,
    }

    impl Poller {
        pub(super) fn new() -> io::Result<Poller> {
            let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if epfd < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(Poller { epfd })
        }

        pub(super) fn ctl(
            &self,
            op: Op,
            fd: RawFd,
            token: u64,
            readable: bool,
            writable: bool,
        ) -> io::Result<()> {
            let mut events = EPOLLRDHUP;
            if readable {
                events |= EPOLLIN;
            }
            if writable {
                events |= EPOLLOUT;
            }
            let mut ev = EpollEvent { events, data: token };
            let op = match op {
                Op::Add => EPOLL_CTL_ADD,
                Op::Modify => EPOLL_CTL_MOD,
                Op::Delete => EPOLL_CTL_DEL,
            };
            let rc = unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) };
            if rc < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        pub(super) fn wait(&self, out: &mut Vec<Event>, timeout_ms: i32) -> io::Result<usize> {
            let mut raw = [EpollEvent { events: 0, data: 0 }; 128];
            let n = loop {
                let n = unsafe {
                    epoll_wait(self.epfd, raw.as_mut_ptr(), raw.len() as i32, timeout_ms)
                };
                if n >= 0 {
                    break n as usize;
                }
                let err = io::Error::last_os_error();
                if err.kind() != io::ErrorKind::Interrupted {
                    return Err(err);
                }
            };
            for ev in &raw[..n] {
                let bits = ev.events;
                out.push(Event {
                    token: ev.data,
                    readable: bits & (EPOLLIN | EPOLLHUP | EPOLLRDHUP | EPOLLERR) != 0,
                    writable: bits & EPOLLOUT != 0,
                    closed: bits & (EPOLLHUP | EPOLLRDHUP | EPOLLERR) != 0,
                });
            }
            Ok(n)
        }
    }

    impl Drop for Poller {
        fn drop(&mut self) {
            unsafe {
                close(self.epfd);
            }
        }
    }
}

#[cfg(any(target_os = "macos", target_os = "ios"))]
mod imp {
    use super::Event;
    use std::io;
    use std::os::unix::io::RawFd;

    #[repr(C)]
    struct Kevent {
        ident: usize,
        filter: i16,
        flags: u16,
        fflags: u32,
        data: isize,
        udata: *mut std::ffi::c_void,
    }

    #[repr(C)]
    struct Timespec {
        tv_sec: isize,
        tv_nsec: isize,
    }

    extern "C" {
        fn kqueue() -> i32;
        fn kevent(
            kq: i32,
            changelist: *const Kevent,
            nchanges: i32,
            eventlist: *mut Kevent,
            nevents: i32,
            timeout: *const Timespec,
        ) -> i32;
        fn close(fd: i32) -> i32;
    }

    const EVFILT_READ: i16 = -1;
    const EVFILT_WRITE: i16 = -2;
    const EV_ADD: u16 = 0x0001;
    const EV_DELETE: u16 = 0x0002;
    const EV_ENABLE: u16 = 0x0004;
    const EV_DISABLE: u16 = 0x0008;
    const EV_EOF: u16 = 0x8000;
    const EV_ERROR: u16 = 0x4000;

    #[derive(Debug)]
    pub(super) struct Poller {
        kq: RawFd,
    }

    pub(super) enum Op {
        Add,
        Modify,
        Delete,
    }

    impl Poller {
        pub(super) fn new() -> io::Result<Poller> {
            let kq = unsafe { kqueue() };
            if kq < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(Poller { kq })
        }

        fn apply(&self, changes: &[Kevent]) -> io::Result<()> {
            let rc = unsafe {
                kevent(
                    self.kq,
                    changes.as_ptr(),
                    changes.len() as i32,
                    std::ptr::null_mut(),
                    0,
                    std::ptr::null(),
                )
            };
            if rc < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        pub(super) fn ctl(
            &self,
            op: Op,
            fd: RawFd,
            token: u64,
            readable: bool,
            writable: bool,
        ) -> io::Result<()> {
            let ev = |filter: i16, flags: u16| Kevent {
                ident: fd as usize,
                filter,
                flags,
                fflags: 0,
                data: 0,
                udata: token as *mut std::ffi::c_void,
            };
            match op {
                Op::Add | Op::Modify => {
                    // both filters are always registered; interest is
                    // toggled via enable/disable so Modify never races
                    // a missing filter
                    let rd = if readable { EV_ENABLE } else { EV_DISABLE };
                    let wr = if writable { EV_ENABLE } else { EV_DISABLE };
                    self.apply(&[
                        ev(EVFILT_READ, EV_ADD | rd),
                        ev(EVFILT_WRITE, EV_ADD | wr),
                    ])
                }
                Op::Delete => {
                    // a filter may not exist (never enabled): ignore
                    // per-change errors by deleting one at a time
                    let _ = self.apply(&[ev(EVFILT_READ, EV_DELETE)]);
                    let _ = self.apply(&[ev(EVFILT_WRITE, EV_DELETE)]);
                    Ok(())
                }
            }
        }

        pub(super) fn wait(&self, out: &mut Vec<Event>, timeout_ms: i32) -> io::Result<usize> {
            let ts;
            let ts_ptr = if timeout_ms < 0 {
                std::ptr::null()
            } else {
                ts = Timespec {
                    tv_sec: (timeout_ms / 1000) as isize,
                    tv_nsec: ((timeout_ms % 1000) * 1_000_000) as isize,
                };
                &ts as *const Timespec
            };
            let mut raw: Vec<Kevent> = Vec::with_capacity(128);
            let n = loop {
                let n = unsafe {
                    kevent(self.kq, std::ptr::null(), 0, raw.as_mut_ptr(), 128, ts_ptr)
                };
                if n >= 0 {
                    break n as usize;
                }
                let err = io::Error::last_os_error();
                if err.kind() != io::ErrorKind::Interrupted {
                    return Err(err);
                }
            };
            unsafe { raw.set_len(n) };
            for ev in &raw {
                let closed = ev.flags & (EV_EOF | EV_ERROR) != 0;
                out.push(Event {
                    token: ev.udata as u64,
                    readable: ev.filter == EVFILT_READ || closed,
                    writable: ev.filter == EVFILT_WRITE,
                    closed,
                });
            }
            Ok(n)
        }
    }

    impl Drop for Poller {
        fn drop(&mut self) {
            unsafe {
                close(self.kq);
            }
        }
    }
}

#[cfg(not(any(
    target_os = "linux",
    target_os = "android",
    target_os = "macos",
    target_os = "ios"
)))]
mod imp {
    use super::Event;
    use std::io;
    use std::os::unix::io::RawFd;

    #[derive(Debug)]
    pub(super) struct Poller {
        _never: std::convert::Infallible,
    }

    pub(super) enum Op {
        Add,
        Modify,
        Delete,
    }

    impl Poller {
        pub(super) fn new() -> io::Result<Poller> {
            Err(io::Error::new(
                io::ErrorKind::Unsupported,
                "no readiness-poll backend on this platform (epoll/kqueue only)",
            ))
        }

        pub(super) fn ctl(
            &self,
            _op: Op,
            _fd: RawFd,
            _token: u64,
            _readable: bool,
            _writable: bool,
        ) -> io::Result<()> {
            match self._never {}
        }

        pub(super) fn wait(&self, _out: &mut Vec<Event>, _timeout_ms: i32) -> io::Result<usize> {
            match self._never {}
        }
    }
}

#[cfg(all(test, any(target_os = "linux", target_os = "android", target_os = "macos")))]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::os::unix::io::AsRawFd;
    use std::os::unix::net::UnixStream;

    #[test]
    fn readable_event_fires_with_token() {
        let (mut a, b) = UnixStream::pair().unwrap();
        b.set_nonblocking(true).unwrap();
        let poller = Poller::new().unwrap();
        poller.add(b.as_raw_fd(), 42, true, false).unwrap();

        let mut events = Vec::new();
        assert_eq!(poller.wait(&mut events, 0).unwrap(), 0, "idle fd: no events");

        a.write_all(b"ping").unwrap();
        let n = poller.wait(&mut events, 2000).unwrap();
        assert_eq!(n, 1);
        assert_eq!(events[0].token, 42);
        assert!(events[0].readable);

        // level-triggered: still readable until drained
        events.clear();
        assert_eq!(poller.wait(&mut events, 0).unwrap(), 1);
        let mut buf = [0u8; 16];
        let mut b2 = &b;
        let _ = b2.read(&mut buf).unwrap();
        events.clear();
        assert_eq!(poller.wait(&mut events, 0).unwrap(), 0, "drained: quiet again");
    }

    #[test]
    fn write_interest_toggles_via_modify() {
        let (a, _b) = UnixStream::pair().unwrap();
        a.set_nonblocking(true).unwrap();
        let poller = Poller::new().unwrap();
        poller.add(a.as_raw_fd(), 7, true, false).unwrap();
        let mut events = Vec::new();
        assert_eq!(poller.wait(&mut events, 0).unwrap(), 0, "no write interest yet");

        poller.modify(a.as_raw_fd(), 7, true, true).unwrap();
        let n = poller.wait(&mut events, 2000).unwrap();
        assert!(n >= 1);
        assert!(events.iter().any(|e| e.token == 7 && e.writable));

        poller.modify(a.as_raw_fd(), 7, true, false).unwrap();
        events.clear();
        assert_eq!(poller.wait(&mut events, 0).unwrap(), 0);
    }

    #[test]
    fn hangup_reports_closed() {
        let (a, b) = UnixStream::pair().unwrap();
        let poller = Poller::new().unwrap();
        poller.add(b.as_raw_fd(), 9, true, false).unwrap();
        drop(a);
        let mut events = Vec::new();
        let n = poller.wait(&mut events, 2000).unwrap();
        assert!(n >= 1);
        assert!(events[0].readable, "EOF surfaces as readable (read returns 0)");
        poller.delete(b.as_raw_fd()).unwrap();
    }
}
