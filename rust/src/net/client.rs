//! Wire clients: the blocking single-connection [`NetClient`] used by
//! tests/benches, and the [`ReconnectingClient`] used by
//! `tablenet client` — which survives server restarts by retrying
//! idempotency-keyed requests under an explicit token-bucket retry
//! budget with a deterministic capped-jittered backoff schedule.
//! Pure `std` — works on every platform even where the server's poll
//! backend does not.
//!
//! # Exactly-once across reconnects
//!
//! Every request carries a per-client idempotency key (stamped from a
//! monotonic counter, never 0) and the client announces a stable
//! `client_id` in its `Hello`. The server's replay cache answers a
//! retried `(client_id, key)` with the original verdicts instead of
//! re-submitting rows, so a reply lost to a dropped connection is
//! retried safely: the rows are acknowledged at most once.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use crate::util::Rng;

use super::admission::TokenBucket;
use super::proto::{
    decode_payload, encode_frame, Deframer, Frame, Hello, InferReply, InferRequest, RowReply,
    Status, MAX_FRAME_BYTES,
};

/// A blocking protocol client over one TCP connection.
pub struct NetClient {
    stream: TcpStream,
    deframer: Deframer,
    buf: Vec<u8>,
}

impl NetClient {
    /// Connect (blocking) with `TCP_NODELAY` set.
    pub fn connect(addr: &str) -> std::io::Result<NetClient> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        Ok(NetClient {
            stream,
            deframer: Deframer::new(MAX_FRAME_BYTES),
            buf: vec![0u8; 16 * 1024],
        })
    }

    /// Connect, retrying on refusal for up to `for_ms` — covers the
    /// race where the server process is still binding its listener.
    pub fn connect_retry(addr: &str, for_ms: u64) -> std::io::Result<NetClient> {
        let deadline = Instant::now() + Duration::from_millis(for_ms);
        loop {
            match NetClient::connect(addr) {
                Ok(c) => return Ok(c),
                Err(e) => {
                    if Instant::now() >= deadline {
                        return Err(e);
                    }
                    std::thread::sleep(Duration::from_millis(25));
                }
            }
        }
    }

    /// Announce this client (and present the shared-secret token, if
    /// the server demands one). No reply is sent on success; a wrong
    /// token comes back as a typed `AuthFailed` error frame.
    pub fn hello(&mut self, client_id: u64, token: &str) -> std::io::Result<()> {
        let mut wire = Vec::new();
        encode_frame(
            &Frame::Hello(Hello { client_id, token: token.to_string() }),
            &mut wire,
        );
        self.stream.write_all(&wire)
    }

    /// Send one request frame (`rows * features` values, row-major)
    /// without waiting for the reply — supports pipelining. Unkeyed
    /// (`key` 0): the reply is never replay-cached.
    pub fn send(&mut self, model: &str, features: u32, data: &[f32]) -> std::io::Result<()> {
        self.send_keyed(0, model, features, data)
    }

    /// [`send`](Self::send) stamped with an idempotency key (echoed in
    /// the reply; `0` means unkeyed).
    pub fn send_keyed(
        &mut self,
        key: u64,
        model: &str,
        features: u32,
        data: &[f32],
    ) -> std::io::Result<()> {
        let mut wire = Vec::with_capacity(24 + data.len() * 4);
        encode_frame(
            &Frame::Request(InferRequest {
                key,
                model: model.to_string(),
                features,
                data: data.to_vec(),
            }),
            &mut wire,
        );
        self.stream.write_all(&wire)
    }

    /// Block until the next complete frame arrives and decode it.
    pub fn read_frame(&mut self) -> std::io::Result<Frame> {
        loop {
            match self.deframer.next_payload() {
                Ok(Some(payload)) => {
                    return decode_payload(&payload).map_err(|e| {
                        std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string())
                    });
                }
                Ok(None) => {}
                Err(e) => {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::InvalidData,
                        e.to_string(),
                    ));
                }
            }
            let n = self.stream.read(&mut self.buf)?;
            if n == 0 {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "server closed the connection mid-frame",
                ));
            }
            self.deframer.extend(&self.buf[..n]);
        }
    }

    /// Send one frame and block for its reply (request-response mode).
    pub fn infer(&mut self, model: &str, features: u32, data: &[f32]) -> std::io::Result<Frame> {
        self.send(model, features, data)?;
        self.read_frame()
    }

    /// Read timeout for [`read_frame`](Self::read_frame) (None = block
    /// forever).
    pub fn set_read_timeout(&self, timeout: Option<Duration>) -> std::io::Result<()> {
        self.stream.set_read_timeout(timeout)
    }

    /// Half-close the write side so the server sees EOF after the last
    /// in-flight reply.
    pub fn finish_writes(&self) -> std::io::Result<()> {
        self.stream.shutdown(std::net::Shutdown::Write)
    }
}

// ---- retry policy ---------------------------------------------------------

/// Retry governance for [`ReconnectingClient`]: an explicit token
/// budget (every retry — reconnect or re-send — spends one token) and
/// a deterministic capped-jittered backoff schedule.
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Retry tokens available at burst (the bucket capacity).
    pub budget: u64,
    /// Token refill rate per second (`0.0` = a fixed, non-renewing
    /// budget).
    pub refill_per_sec: f64,
    /// First backoff step; doubles per consecutive retry.
    pub base: Duration,
    /// Backoff ceiling.
    pub cap: Duration,
    /// Jitter seed: the whole backoff schedule is a pure function of
    /// `(seed, attempt)`, so a fixed seed reproduces the exact sleeps.
    pub seed: u64,
    /// Socket read timeout while waiting for a reply; a timeout is a
    /// transport error and follows the retry path (safe: the request
    /// is idempotency-keyed). `None` blocks forever.
    pub read_timeout: Option<Duration>,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            budget: 8,
            refill_per_sec: 0.5,
            base: Duration::from_millis(50),
            cap: Duration::from_secs(2),
            seed: 0x7ab1e,
            read_timeout: Some(Duration::from_secs(10)),
        }
    }
}

impl RetryPolicy {
    /// The backoff before retry number `attempt` (0-based): capped
    /// doubling from [`base`](Self::base), scaled by a jitter factor
    /// in `[0.5, 1.0)` drawn deterministically from
    /// `(seed, attempt)`.
    pub fn backoff_schedule(&self, attempt: u32) -> Duration {
        let exp = attempt.min(16);
        let ceiling = self.base.saturating_mul(1u32 << exp).min(self.cap);
        let mut rng = Rng::new(
            self.seed
                ^ u64::from(attempt)
                    .wrapping_mul(0x9e37_79b9_7f4a_7c15)
                    .wrapping_add(0x5851_f42d_4c95_7f2d),
        );
        let jitter = 0.5 + 0.5 * rng.f64();
        Duration::from_secs_f64(ceiling.as_secs_f64() * jitter)
    }
}

// ---- reconnecting client --------------------------------------------------

/// Counters describing how hard a [`ReconnectingClient`] had to work.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RetryStats {
    /// Connections established (including the first).
    pub connects: u64,
    /// Retries spent from the budget (reconnects + re-sends).
    pub retries: u64,
    /// Retries refused because the budget was empty.
    pub budget_denied: u64,
    /// `GoAway` drain notices observed.
    pub goaways_seen: u64,
}

/// What one exchange on the wire produced, before retry logic.
enum Exchange {
    /// The reply for our key.
    Reply(InferReply),
    /// A frame-level typed error.
    Refused(Status),
}

/// A wire client that survives dropped connections and server
/// restarts: requests are idempotency-keyed, replies are matched by
/// key, and every retry (reconnect or re-send) spends a token from the
/// [`RetryPolicy`] budget with deterministic capped-jittered backoff
/// between attempts. Terminal statuses (`Malformed`, `UnknownModel`,
/// `AuthFailed`) are never retried — they come back as typed per-row
/// error verdicts.
pub struct ReconnectingClient {
    addr: String,
    client_id: u64,
    token: String,
    policy: RetryPolicy,
    budget: TokenBucket,
    inner: Option<NetClient>,
    next_key: u64,
    draining: bool,
    stats: RetryStats,
}

impl ReconnectingClient {
    /// Create a client for `addr`. `client_id` must be nonzero and
    /// stable for the client's lifetime (it namespaces the server-side
    /// replay cache); `token` is the shared auth secret (empty when
    /// the server runs without auth). Connects lazily on first use.
    pub fn new(addr: &str, client_id: u64, token: &str, policy: RetryPolicy) -> ReconnectingClient {
        let budget = TokenBucket::new(policy.budget, policy.refill_per_sec);
        ReconnectingClient {
            addr: addr.to_string(),
            client_id: client_id.max(1),
            token: token.to_string(),
            policy,
            budget,
            inner: None,
            next_key: 1,
            draining: false,
            stats: RetryStats::default(),
        }
    }

    /// Retry counters so far.
    pub fn stats(&self) -> RetryStats {
        self.stats
    }

    /// The key the next request will be stamped with.
    pub fn next_key(&self) -> u64 {
        self.next_key
    }

    /// Send one request and block until it is definitively resolved:
    /// `Ok` with the reply (possibly all-error rows for a terminal
    /// refusal), or `Err` when the transport failed and the retry
    /// budget is exhausted. Rows acknowledged `Ok` are acknowledged
    /// exactly once across any number of reconnects (see module docs).
    pub fn infer(
        &mut self,
        model: &str,
        features: u32,
        data: &[f32],
    ) -> std::io::Result<InferReply> {
        let key = self.next_key;
        self.next_key += 1;
        let rows = if features == 0 { 0 } else { data.len() / features as usize };
        let mut attempt: u32 = 0;
        loop {
            if self.inner.is_none() {
                let connected = NetClient::connect_retry(&self.addr, 1_000).and_then(|mut c| {
                    c.set_read_timeout(self.policy.read_timeout)?;
                    c.hello(self.client_id, &self.token)?;
                    Ok(c)
                });
                match connected {
                    Ok(c) => {
                        self.inner = Some(c);
                        self.draining = false;
                        self.stats.connects += 1;
                    }
                    Err(e) => {
                        if !self.spend(&mut attempt) {
                            return Err(budget_exhausted(e.to_string()));
                        }
                        continue;
                    }
                }
            }
            let outcome = self.exchange(key, model, features, data);
            if self.draining {
                // the server said GoAway: finish this exchange, then
                // abandon the connection so the next attempt lands on
                // a live (possibly restarted) listener
                self.inner = None;
                self.draining = false;
            }
            match outcome {
                Ok(Exchange::Reply(r)) => return Ok(r),
                Ok(Exchange::Refused(status)) => {
                    if !status.is_retryable() {
                        return Ok(refused_reply(key, rows, status));
                    }
                    if matches!(status, Status::ShutDown | Status::TooManyConnections) {
                        // this server is going away (or full): retry on
                        // a fresh connection after backoff
                        self.inner = None;
                    }
                    if !self.spend(&mut attempt) {
                        return Ok(refused_reply(key, rows, status));
                    }
                }
                Err(e) => {
                    self.inner = None;
                    if !self.spend(&mut attempt) {
                        return Err(budget_exhausted(e.to_string()));
                    }
                }
            }
        }
    }

    /// One send + matching read on the current connection.
    fn exchange(
        &mut self,
        key: u64,
        model: &str,
        features: u32,
        data: &[f32],
    ) -> std::io::Result<Exchange> {
        let conn = self.inner.as_mut().expect("exchange requires a connection");
        conn.send_keyed(key, model, features, data)?;
        loop {
            match conn.read_frame()? {
                Frame::Reply(r) if r.key == key => return Ok(Exchange::Reply(r)),
                // a stale reply for an abandoned exchange: skip it
                Frame::Reply(_) => continue,
                Frame::Error(e) => return Ok(Exchange::Refused(e.status)),
                Frame::GoAway(_) => {
                    self.stats.goaways_seen += 1;
                    self.draining = true;
                    // the server still answers in-flight requests
                    continue;
                }
                _ => {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::InvalidData,
                        "unexpected frame kind from server",
                    ));
                }
            }
        }
    }

    /// Spend one retry token and sleep the deterministic backoff.
    /// `false` means the budget is empty.
    fn spend(&mut self, attempt: &mut u32) -> bool {
        if !self.budget.take_now(1) {
            self.stats.budget_denied += 1;
            return false;
        }
        self.stats.retries += 1;
        let pause = self.policy.backoff_schedule(*attempt);
        *attempt += 1;
        std::thread::sleep(pause);
        true
    }
}

/// The reply handed back for a terminal (or budget-final) frame-level
/// refusal: every row carries the typed error verdict.
fn refused_reply(key: u64, rows: usize, status: Status) -> InferReply {
    InferReply { key, rows: (0..rows).map(|_| RowReply::error(status)).collect() }
}

fn budget_exhausted(last: String) -> std::io::Error {
    std::io::Error::other(format!("retry budget exhausted (last error: {last})"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_schedule_is_deterministic_capped_and_jittered() {
        let p = RetryPolicy {
            budget: 4,
            refill_per_sec: 0.0,
            base: Duration::from_millis(50),
            cap: Duration::from_secs(2),
            seed: 0xfeed,
            read_timeout: None,
        };
        let a: Vec<Duration> = (0..20).map(|i| p.backoff_schedule(i)).collect();
        let b: Vec<Duration> = (0..20).map(|i| p.backoff_schedule(i)).collect();
        assert_eq!(a, b, "fixed seed must reproduce the exact schedule");

        for (i, d) in a.iter().enumerate() {
            let ceiling = p.base.saturating_mul(1u32 << (i as u32).min(16)).min(p.cap);
            assert!(*d <= ceiling, "attempt {i}: {d:?} over ceiling {ceiling:?}");
            assert!(
                *d >= ceiling.mul_f64(0.499),
                "attempt {i}: {d:?} under half-ceiling {ceiling:?}"
            );
        }
        // deep attempts saturate at the cap, never overflow past it
        assert!(a[19] <= p.cap);

        let q = RetryPolicy { seed: 0xbeef, ..p.clone() };
        let c: Vec<Duration> = (0..20).map(|i| q.backoff_schedule(i)).collect();
        assert_ne!(a, c, "different seeds must jitter differently");
    }

    #[test]
    fn keys_start_at_one_and_climb() {
        let c = ReconnectingClient::new("127.0.0.1:1", 7, "", RetryPolicy::default());
        assert_eq!(c.next_key(), 1, "key 0 is reserved for unkeyed requests");
        assert_eq!(c.stats(), RetryStats::default());
    }
}
