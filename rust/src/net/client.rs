//! Blocking wire client: one TCP connection speaking the `LTN1`
//! protocol, used by `tablenet client` for load generation and by the
//! integration tests/benches. Pure `std` — works on every platform
//! even where the server's poll backend does not.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use super::proto::{
    decode_payload, encode_frame, Deframer, Frame, InferRequest, MAX_FRAME_BYTES,
};

/// A blocking protocol client over one TCP connection.
pub struct NetClient {
    stream: TcpStream,
    deframer: Deframer,
    buf: Vec<u8>,
}

impl NetClient {
    /// Connect (blocking) with `TCP_NODELAY` set.
    pub fn connect(addr: &str) -> std::io::Result<NetClient> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        Ok(NetClient {
            stream,
            deframer: Deframer::new(MAX_FRAME_BYTES),
            buf: vec![0u8; 16 * 1024],
        })
    }

    /// Connect, retrying on refusal for up to `for_ms` — covers the
    /// race where the server process is still binding its listener.
    pub fn connect_retry(addr: &str, for_ms: u64) -> std::io::Result<NetClient> {
        let deadline = Instant::now() + Duration::from_millis(for_ms);
        loop {
            match NetClient::connect(addr) {
                Ok(c) => return Ok(c),
                Err(e) => {
                    if Instant::now() >= deadline {
                        return Err(e);
                    }
                    std::thread::sleep(Duration::from_millis(25));
                }
            }
        }
    }

    /// Send one request frame (`rows * features` values, row-major)
    /// without waiting for the reply — supports pipelining.
    pub fn send(&mut self, model: &str, features: u32, data: &[f32]) -> std::io::Result<()> {
        let mut wire = Vec::with_capacity(16 + data.len() * 4);
        encode_frame(
            &Frame::Request(InferRequest {
                model: model.to_string(),
                features,
                data: data.to_vec(),
            }),
            &mut wire,
        );
        self.stream.write_all(&wire)
    }

    /// Block until the next complete frame arrives and decode it.
    pub fn read_frame(&mut self) -> std::io::Result<Frame> {
        loop {
            match self.deframer.next_payload() {
                Ok(Some(payload)) => {
                    return decode_payload(&payload).map_err(|e| {
                        std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string())
                    });
                }
                Ok(None) => {}
                Err(e) => {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::InvalidData,
                        e.to_string(),
                    ));
                }
            }
            let n = self.stream.read(&mut self.buf)?;
            if n == 0 {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "server closed the connection mid-frame",
                ));
            }
            self.deframer.extend(&self.buf[..n]);
        }
    }

    /// Send one frame and block for its reply (request-response mode).
    pub fn infer(&mut self, model: &str, features: u32, data: &[f32]) -> std::io::Result<Frame> {
        self.send(model, features, data)?;
        self.read_frame()
    }

    /// Read timeout for [`read_frame`](Self::read_frame) (None = block
    /// forever).
    pub fn set_read_timeout(&self, timeout: Option<Duration>) -> std::io::Result<()> {
        self.stream.set_read_timeout(timeout)
    }

    /// Half-close the write side so the server sees EOF after the last
    /// in-flight reply.
    pub fn finish_writes(&self) -> std::io::Result<()> {
        self.stream.shutdown(std::net::Shutdown::Write)
    }
}
