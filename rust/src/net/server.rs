//! Thread-per-core socket serving: nonblocking accept + readiness
//! polling ([`Poller`]) on N reactor threads, each owning its accepted
//! connections end-to-end. A reactor parses frames, runs the hardening
//! gates (auth, per-connection rate limits, connection cap) and
//! admission, and hands whole request frames to its paired dispatcher
//! thread, which submits every row into the existing
//! [`FleetClient`](crate::coordinator::registry::FleetClient) path —
//! so hot swaps, deadlines, load shedding, panic isolation and the
//! exact accounting invariant all hold unchanged for socket traffic.
//!
//! ```text
//!                 ┌───────────────┐  frames   ┌──────────────────┐
//!  conns ──────▶  │ net-reactor-k │ ────────▶ │ net-dispatch-k   │
//!  (epoll/kqueue) │ parse+admit   │ ◀──────── │ submit rows into │
//!                 └───────────────┘  replies  │ FleetClient      │
//!                                             └──────────────────┘
//! ```
//!
//! Ordering contract: replies on one connection come back in request
//! order (one dispatcher per reactor, frames processed FIFO, rows
//! inside a frame kept in submit order). A dispatcher blocking on one
//! slow frame delays other frames of the *same reactor* only; scale
//! `--net-threads` to isolate tenants.
//!
//! # Request gauntlet
//!
//! Each request frame passes, in order: drain refusal (`ShutDown`),
//! auth (`AuthFailed`, fails the connection closed), per-connection
//! frame/row token buckets (`RateLimited`, connection stays open),
//! replay-cache lookup (cached replies for already-answered
//! idempotency keys are re-sent without re-submitting a single row),
//! model resolution (`UnknownModel`), then the shared row-budget
//! [`AdmissionController`] (`AdmissionRejected`). Every rejection is a
//! typed error frame and a dedicated counter — nothing is silently
//! dropped.
//!
//! # Drain lifecycle
//!
//! [`NetServer::begin_drain`] (or [`shutdown`](NetServer::shutdown) /
//! drop) flips the shared drain flag. Each reactor then deletes its
//! listener registration (no new connections), sends one
//! `GoAway{reason, grace_ms}` frame to every live v2 connection,
//! finishes in-flight rows and answers newly arriving requests with
//! typed `ShutDown` errors. Connections that have not gone idle after
//! `grace_ms` are force-closed so a peer that never reads cannot hang
//! the drain; rows still in flight at that point are completed and
//! accounted by the dispatcher, only their reply bytes are dropped —
//! the client retries them under the same idempotency key.

use std::collections::{BTreeMap, HashMap, HashSet, VecDeque};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::registry::FleetClient;
use crate::coordinator::Client;

use super::admission::{AdmissionController, TokenBucket};
use super::metrics::{ConnIngress, NetMetrics, NetSnapshot};
use super::poll::Poller;
use super::proto::{
    decode_payload_versioned, encode_frame, encode_frame_at, Deframer, ErrorReply, Frame,
    GoAway, InferReply, InferRequest, RowReply, Status, MAX_FRAME_BYTES,
};

const TOKEN_LISTENER: u64 = 0;
const TOKEN_WAKE: u64 = 1;
const TOKEN_BASE: u64 = 2;
const READ_CHUNK: usize = 16 * 1024;
/// Cross-connection replay-cache capacity: completed keyed replies
/// retained so a client retrying after a dropped connection gets the
/// original verdicts back instead of a double submission.
const REPLAY_CACHE_ENTRIES: usize = 4096;

/// Tuning knobs for [`NetServer::start`].
#[derive(Debug, Clone)]
pub struct NetServerOptions {
    /// Reactor thread count; `0` = one per available core.
    pub threads: usize,
    /// Per-frame payload cap (default [`MAX_FRAME_BYTES`]).
    pub max_frame_bytes: usize,
    /// Set `TCP_NODELAY` on accepted connections.
    pub nodelay: bool,
    /// Shared-secret auth token. When set, a connection must present
    /// it in a `Hello` frame before its first request; a missing or
    /// wrong token fails the connection closed with `AuthFailed`.
    pub auth_token: Option<String>,
    /// Server-wide cap on concurrently open connections (`0` = no
    /// cap). Connections over the cap are answered with a typed
    /// `TooManyConnections` error and closed.
    pub max_conns: usize,
    /// Per-connection request-frame rate limit in frames/second
    /// (`0` = off). Burst capacity is one second's worth.
    pub frame_rate_limit: u64,
    /// Per-connection row rate limit in rows/second (`0` = off). A
    /// frame carrying more rows than one second's budget can never be
    /// admitted on that connection — size the limit above the largest
    /// legitimate frame.
    pub row_rate_limit: u64,
    /// Grace period advertised in `GoAway` and enforced on drain:
    /// connections still unfinished this long after the drain began
    /// are force-closed (their in-flight rows complete and are
    /// accounted; only the reply bytes are dropped).
    pub drain_grace_ms: u32,
}

impl Default for NetServerOptions {
    fn default() -> Self {
        NetServerOptions {
            threads: 0,
            max_frame_bytes: MAX_FRAME_BYTES,
            nodelay: true,
            auth_token: None,
            max_conns: 0,
            frame_rate_limit: 0,
            row_rate_limit: 0,
            drain_grace_ms: 5_000,
        }
    }
}

// ---- drain signal ---------------------------------------------------------

static DRAIN_SIGNAL: AtomicBool = AtomicBool::new(false);

extern "C" fn on_drain_signal(_sig: i32) {
    // async-signal-safe: a single atomic store, nothing else
    DRAIN_SIGNAL.store(true, Ordering::Relaxed);
}

/// Install a `SIGTERM`/`SIGINT` handler that latches a process-wide
/// drain flag (readable via [`drain_signal_received`]) instead of
/// killing the process, so `tablenet serve` can GoAway-drain and exit
/// with the wire ledger balanced. Idempotent.
pub fn install_drain_signal_handler() {
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGTERM, on_drain_signal);
        signal(SIGINT, on_drain_signal);
    }
}

/// Whether a drain signal has been received since
/// [`install_drain_signal_handler`] was called.
pub fn drain_signal_received() -> bool {
    DRAIN_SIGNAL.load(Ordering::Relaxed)
}

// ---- listener binding -----------------------------------------------------

/// Bind a listener with `SO_REUSEADDR`, so a restarted server can
/// rebind the port its predecessor's drained connections still hold in
/// `TIME_WAIT` (the server is the active closer on drain). IPv4 only —
/// other address families fall back to a plain `std` bind.
fn bind_reuseaddr(addr: &str) -> std::io::Result<TcpListener> {
    use std::net::ToSocketAddrs;
    let sa = addr.to_socket_addrs()?.next().ok_or_else(|| {
        std::io::Error::new(std::io::ErrorKind::InvalidInput, "address resolved to nothing")
    })?;
    match sa {
        SocketAddr::V4(v4) => bind_reuseaddr_v4(v4).or_else(|_| TcpListener::bind(sa)),
        SocketAddr::V6(_) => TcpListener::bind(sa),
    }
}

fn bind_reuseaddr_v4(sa: std::net::SocketAddrV4) -> std::io::Result<TcpListener> {
    use std::os::unix::io::FromRawFd;

    // the kernel's struct sockaddr_in, network byte order in place
    #[repr(C)]
    struct SockAddrIn {
        #[cfg(any(target_os = "macos", target_os = "ios"))]
        sin_len: u8,
        #[cfg(any(target_os = "macos", target_os = "ios"))]
        sin_family: u8,
        #[cfg(not(any(target_os = "macos", target_os = "ios")))]
        sin_family: u16,
        sin_port: u16,
        sin_addr: u32,
        sin_zero: [u8; 8],
    }
    extern "C" {
        fn socket(domain: i32, ty: i32, protocol: i32) -> i32;
        fn setsockopt(
            fd: i32,
            level: i32,
            name: i32,
            value: *const std::ffi::c_void,
            len: u32,
        ) -> i32;
        fn bind(fd: i32, addr: *const std::ffi::c_void, len: u32) -> i32;
        fn listen(fd: i32, backlog: i32) -> i32;
        fn close(fd: i32) -> i32;
    }
    const AF_INET: i32 = 2;
    #[cfg(any(target_os = "linux", target_os = "android"))]
    const SOCK_STREAM: i32 = 1 | 0o2000000; // | SOCK_CLOEXEC
    #[cfg(not(any(target_os = "linux", target_os = "android")))]
    const SOCK_STREAM: i32 = 1;
    #[cfg(any(target_os = "macos", target_os = "ios"))]
    const SOL_SOCKET: i32 = 0xffff;
    #[cfg(not(any(target_os = "macos", target_os = "ios")))]
    const SOL_SOCKET: i32 = 1;
    #[cfg(any(target_os = "macos", target_os = "ios"))]
    const SO_REUSEADDR: i32 = 0x0004;
    #[cfg(not(any(target_os = "macos", target_os = "ios")))]
    const SO_REUSEADDR: i32 = 2;

    unsafe {
        let fd = socket(AF_INET, SOCK_STREAM, 0);
        if fd < 0 {
            return Err(std::io::Error::last_os_error());
        }
        let fail = |fd: i32| {
            let e = std::io::Error::last_os_error();
            close(fd);
            Err(e)
        };
        let one: i32 = 1;
        if setsockopt(
            fd,
            SOL_SOCKET,
            SO_REUSEADDR,
            &one as *const i32 as *const std::ffi::c_void,
            std::mem::size_of::<i32>() as u32,
        ) < 0
        {
            return fail(fd);
        }
        let sin = SockAddrIn {
            #[cfg(any(target_os = "macos", target_os = "ios"))]
            sin_len: std::mem::size_of::<SockAddrIn>() as u8,
            #[cfg(any(target_os = "macos", target_os = "ios"))]
            sin_family: AF_INET as u8,
            #[cfg(not(any(target_os = "macos", target_os = "ios")))]
            sin_family: AF_INET as u16,
            sin_port: sa.port().to_be(),
            sin_addr: u32::from_ne_bytes(sa.ip().octets()),
            sin_zero: [0u8; 8],
        };
        if bind(
            fd,
            &sin as *const SockAddrIn as *const std::ffi::c_void,
            std::mem::size_of::<SockAddrIn>() as u32,
        ) < 0
        {
            return fail(fd);
        }
        if listen(fd, 1024) < 0 {
            return fail(fd);
        }
        Ok(TcpListener::from_raw_fd(fd))
    }
}

// ---- replay cache ---------------------------------------------------------

/// What the replay cache knows about a `(client_id, key)` pair.
enum ReplayState {
    /// Already answered: the encoded reply frame and its row count.
    Done(Vec<u8>, u64),
    /// Submitted but not yet completed by a dispatcher.
    Pending,
    /// Never seen.
    New,
}

/// Bounded cross-connection cache of completed keyed replies, shared
/// by every reactor and dispatcher so a retry after reconnect lands on
/// the cached verdicts regardless of which reactor owns the new
/// connection.
struct ReplayCache {
    cap: usize,
    done: HashMap<(u64, u64), (Vec<u8>, u64)>,
    order: VecDeque<(u64, u64)>,
    pending: HashSet<(u64, u64)>,
}

impl ReplayCache {
    fn new(cap: usize) -> ReplayCache {
        ReplayCache {
            cap,
            done: HashMap::new(),
            order: VecDeque::new(),
            pending: HashSet::new(),
        }
    }

    fn state(&self, id: (u64, u64)) -> ReplayState {
        if let Some((bytes, rows)) = self.done.get(&id) {
            return ReplayState::Done(bytes.clone(), *rows);
        }
        if self.pending.contains(&id) {
            return ReplayState::Pending;
        }
        ReplayState::New
    }

    fn begin(&mut self, id: (u64, u64)) {
        self.pending.insert(id);
    }

    fn abort(&mut self, id: (u64, u64)) {
        self.pending.remove(&id);
    }

    fn complete(&mut self, id: (u64, u64), bytes: Vec<u8>, rows: u64) {
        self.pending.remove(&id);
        if self.done.insert(id, (bytes, rows)).is_none() {
            self.order.push_back(id);
        }
        while self.done.len() > self.cap {
            match self.order.pop_front() {
                Some(old) => {
                    self.done.remove(&old);
                }
                None => break,
            }
        }
    }
}

type SharedReplay = Arc<Mutex<ReplayCache>>;

fn lock_replay(replay: &SharedReplay) -> std::sync::MutexGuard<'_, ReplayCache> {
    replay.lock().unwrap_or_else(|e| e.into_inner())
}

// ---- plumbing -------------------------------------------------------------

/// Wakes a reactor out of `Poller::wait` (self-pipe).
struct Waker {
    pipe: UnixStream,
}

impl Waker {
    fn wake(&self) {
        // a full pipe already guarantees a pending wakeup
        let _ = (&self.pipe).write(&[1u8]);
    }
}

/// One frame handed from a reactor to its dispatcher.
struct Dispatch {
    token: u64,
    key: u64,
    client_id: u64,
    peer_version: u8,
    model: String,
    features: usize,
    data: Vec<f32>,
    client: Client,
    t0: Instant,
}

/// One encoded reply travelling back from a dispatcher to its reactor.
struct Completion {
    token: u64,
    bytes: Vec<u8>,
}

struct ReactorHandle {
    waker: Arc<Waker>,
    join: std::thread::JoinHandle<()>,
}

/// A running socket serving tier. Dropping it (or calling
/// [`shutdown`](NetServer::shutdown)) drains in-flight requests,
/// answers anything newly arrived with a typed `ShuttingDown` error,
/// flushes and joins every thread; [`begin_drain`](NetServer::begin_drain)
/// starts the same drain without blocking, broadcasting `GoAway` with
/// a caller-chosen reason first.
pub struct NetServer {
    local_addr: SocketAddr,
    threads: usize,
    shutdown: Arc<AtomicBool>,
    drain_reason: Arc<Mutex<String>>,
    live_conns: Arc<AtomicUsize>,
    reactors: Vec<ReactorHandle>,
    metrics: Arc<NetMetrics>,
    admission: Arc<AdmissionController>,
}

impl NetServer {
    /// Bind `addr` (with `SO_REUSEADDR`, so restarts can rebind
    /// through `TIME_WAIT`) and start serving `fleet` behind
    /// `admission`.
    pub fn start(
        addr: &str,
        fleet: FleetClient,
        admission: Arc<AdmissionController>,
        opts: NetServerOptions,
    ) -> std::io::Result<NetServer> {
        let listener = bind_reuseaddr(addr)?;
        listener.set_nonblocking(true)?;
        let local_addr = listener.local_addr()?;
        // fail at start, not inside a thread, where no poll backend exists
        drop(Poller::new()?);

        let threads = if opts.threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            opts.threads
        }
        .clamp(1, 64);

        let metrics = NetMetrics::new();
        let shutdown = Arc::new(AtomicBool::new(false));
        let drain_reason = Arc::new(Mutex::new(String::from("server shutting down")));
        let live_conns = Arc::new(AtomicUsize::new(0));
        let replay: SharedReplay = Arc::new(Mutex::new(ReplayCache::new(REPLAY_CACHE_ENTRIES)));
        let mut reactors = Vec::with_capacity(threads);
        for i in 0..threads {
            let (wake_tx, wake_rx) = UnixStream::pair()?;
            wake_tx.set_nonblocking(true)?;
            wake_rx.set_nonblocking(true)?;
            let waker = Arc::new(Waker { pipe: wake_tx });
            let completions: Arc<Mutex<Vec<Completion>>> = Arc::new(Mutex::new(Vec::new()));
            let (dispatch_tx, dispatch_rx) = std::sync::mpsc::channel::<Dispatch>();

            let dispatcher = {
                let admission = admission.clone();
                let metrics = metrics.clone();
                let completions = completions.clone();
                let waker = waker.clone();
                let replay = replay.clone();
                std::thread::Builder::new()
                    .name(format!("net-dispatch-{i}"))
                    .spawn(move || {
                        dispatcher_loop(dispatch_rx, admission, metrics, completions, waker, replay)
                    })?
            };

            let reactor = Reactor {
                listener: listener.try_clone()?,
                wake_rx,
                dispatch_tx: Some(dispatch_tx),
                dispatcher: Some(dispatcher),
                completions,
                shutdown: shutdown.clone(),
                drain_reason: drain_reason.clone(),
                live_conns: live_conns.clone(),
                replay: replay.clone(),
                metrics: metrics.clone(),
                admission: admission.clone(),
                fleet: fleet.clone(),
                opts: opts.clone(),
            };
            let join = std::thread::Builder::new()
                .name(format!("net-reactor-{i}"))
                .spawn(move || reactor.run())?;
            reactors.push(ReactorHandle { waker, join });
        }

        Ok(NetServer {
            local_addr,
            threads,
            shutdown,
            drain_reason,
            live_conns,
            reactors,
            metrics,
            admission,
        })
    }

    /// The bound address (resolves `:0` to the chosen port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Reactor thread count actually running.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Live ingress counters (shared with the reactors).
    pub fn metrics(&self) -> &Arc<NetMetrics> {
        &self.metrics
    }

    /// Total rows answered over the wire so far.
    pub fn rows_done(&self) -> u64 {
        self.metrics.rows_done()
    }

    /// Connections currently open.
    pub fn active_connections(&self) -> usize {
        self.live_conns.load(Ordering::SeqCst)
    }

    /// Point-in-time ingress snapshot without stopping the server.
    pub fn snapshot(&self) -> NetSnapshot {
        self.metrics.snapshot(self.admission.snapshot())
    }

    /// Start a graceful drain without blocking: stop accepting, send
    /// `GoAway{reason, grace_ms}` on every v2 connection, finish
    /// in-flight rows, answer new requests with `ShutDown`. Call
    /// [`shutdown`](NetServer::shutdown) afterwards to join the
    /// threads and collect the final snapshot.
    pub fn begin_drain(&self, reason: &str) {
        *self.drain_reason.lock().unwrap_or_else(|e| e.into_inner()) = reason.to_string();
        self.shutdown.store(true, Ordering::SeqCst);
        for r in &self.reactors {
            r.waker.wake();
        }
    }

    /// Whether a drain has started.
    pub fn draining(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    fn stop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        for r in &self.reactors {
            r.waker.wake();
        }
        for r in self.reactors.drain(..) {
            let _ = r.join.join();
        }
    }

    /// Drain, stop every thread and return the final ingress snapshot.
    pub fn shutdown(mut self) -> NetSnapshot {
        self.stop();
        self.metrics.snapshot(self.admission.snapshot())
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.stop();
    }
}

// ---- dispatcher -----------------------------------------------------------

fn dispatcher_loop(
    rx: Receiver<Dispatch>,
    admission: Arc<AdmissionController>,
    metrics: Arc<NetMetrics>,
    completions: Arc<Mutex<Vec<Completion>>>,
    waker: Arc<Waker>,
    replay: SharedReplay,
) {
    while let Ok(d) = rx.recv() {
        let rows = d.data.len() / d.features.max(1);
        // submit every row before waiting on any: rows of one frame
        // land in the ingress queue together and batch together
        let mut pendings = Vec::with_capacity(rows);
        for row in d.data.chunks_exact(d.features) {
            pendings.push(d.client.submit(row.to_vec()));
        }
        let mut out_rows = Vec::with_capacity(rows);
        for p in pendings {
            let verdict = match p {
                Ok(pending) => pending.wait(),
                Err(e) => Err(e),
            };
            let row = match verdict {
                Ok(resp) => RowReply {
                    status: Status::Ok,
                    class: resp.class.min(u16::MAX as usize) as u16,
                    version: resp.version,
                    logits: resp.logits,
                },
                Err(e) => RowReply::error(Status::from_serve_error(&e)),
            };
            metrics.record_row_verdict(&d.model, row.status);
            if row.status == Status::Ok {
                // swap-aware: latency attributed to the artifact
                // version that actually served the row
                metrics.record_version_latency(
                    &d.model,
                    row.version,
                    d.t0.elapsed().as_micros() as f64,
                );
            }
            out_rows.push(row);
        }
        admission.release(&d.model, rows as u64);

        let mut bytes = Vec::new();
        encode_frame_at(
            &Frame::Reply(InferReply { key: d.key, rows: out_rows }),
            d.peer_version,
            &mut bytes,
        );
        metrics.record_frame_out();
        if d.key != 0 && d.client_id != 0 {
            lock_replay(&replay).complete((d.client_id, d.key), bytes.clone(), rows as u64);
        }
        completions.lock().unwrap_or_else(|e| e.into_inner()).push(Completion {
            token: d.token,
            bytes,
        });
        waker.wake();
    }
}

// ---- reactor --------------------------------------------------------------

struct Conn {
    stream: TcpStream,
    token: u64,
    deframer: Deframer,
    out: Vec<u8>,
    out_pos: usize,
    want_write: bool,
    want_read: bool,
    in_flight: usize,
    closing: bool,
    peer_eof: bool,
    dead: bool,
    /// Highest protocol version seen on this connection; replies are
    /// encoded at this version so v1 peers keep decoding.
    peer_version: u8,
    /// Client-chosen id from `Hello` (0 = none): the replay-cache
    /// namespace for this connection's idempotency keys.
    client_id: u64,
    /// Passed the auth gate (always true when no token is required).
    authed: bool,
    frame_bucket: Option<TokenBucket>,
    row_bucket: Option<TokenBucket>,
    stats: ConnIngress,
}

impl Conn {
    fn flushed(&self) -> bool {
        self.out_pos >= self.out.len()
    }

    fn finished(&self, draining: bool) -> bool {
        if self.dead {
            return true;
        }
        if !self.flushed() {
            return false;
        }
        if self.closing {
            return true;
        }
        (self.peer_eof || draining) && self.in_flight == 0
    }
}

struct Reactor {
    listener: TcpListener,
    wake_rx: UnixStream,
    dispatch_tx: Option<Sender<Dispatch>>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
    completions: Arc<Mutex<Vec<Completion>>>,
    shutdown: Arc<AtomicBool>,
    drain_reason: Arc<Mutex<String>>,
    live_conns: Arc<AtomicUsize>,
    replay: SharedReplay,
    metrics: Arc<NetMetrics>,
    admission: Arc<AdmissionController>,
    fleet: FleetClient,
    opts: NetServerOptions,
}

impl Reactor {
    fn run(mut self) {
        let poller = match Poller::new() {
            Ok(p) => p,
            Err(_) => return, // probed at start; cannot happen here
        };
        if poller.add(self.listener.as_raw_fd(), TOKEN_LISTENER, true, false).is_err() {
            return;
        }
        if poller.add(self.wake_rx.as_raw_fd(), TOKEN_WAKE, true, false).is_err() {
            return;
        }

        let mut conns: BTreeMap<u64, Conn> = BTreeMap::new();
        let mut next_token = TOKEN_BASE;
        let mut events = Vec::with_capacity(128);
        let mut listener_armed = true;
        let mut drain_started: Option<Instant> = None;

        loop {
            let draining = self.shutdown.load(Ordering::SeqCst);
            if draining && listener_armed {
                let _ = poller.delete(self.listener.as_raw_fd());
                listener_armed = false;
            }
            if draining && drain_started.is_none() {
                self.broadcast_goaway(&poller, &mut conns);
                drain_started = Some(Instant::now());
            }
            if let Some(t0) = drain_started {
                if t0.elapsed() >= Duration::from_millis(u64::from(self.opts.drain_grace_ms)) {
                    // grace expired: a peer that never reads (or never
                    // closes) must not hang the drain; in-flight rows
                    // still complete and are accounted downstream
                    for conn in conns.values_mut() {
                        conn.dead = true;
                    }
                }
            }
            if draining && conns.is_empty() {
                break;
            }

            events.clear();
            // the waker covers completions and shutdown; the timeout is
            // a belt-and-braces bound so a lost wakeup can only stall,
            // never hang, the drain
            if poller.wait(&mut events, 100).is_err() {
                break;
            }

            self.drain_wake();
            self.apply_completions(&poller, &mut conns);

            for k in 0..events.len() {
                let ev = events[k];
                match ev.token {
                    TOKEN_LISTENER => {
                        if !draining {
                            self.accept_all(&poller, &mut conns, &mut next_token);
                        }
                    }
                    TOKEN_WAKE => self.drain_wake(),
                    token => {
                        if let Some(conn) = conns.get_mut(&token) {
                            if ev.readable {
                                self.handle_readable(conn, draining);
                            }
                            if ev.writable {
                                Self::flush(&self.metrics, conn);
                            }
                            Self::update_interest(&poller, conn);
                        }
                    }
                }
            }

            let done: Vec<u64> =
                conns.values().filter(|c| c.finished(draining)).map(|c| c.token).collect();
            for token in done {
                if let Some(conn) = conns.remove(&token) {
                    let _ = poller.delete(conn.stream.as_raw_fd());
                    self.live_conns.fetch_sub(1, Ordering::SeqCst);
                    self.metrics.record_close(conn.stats);
                }
            }
        }

        for (_, conn) in conns {
            let _ = poller.delete(conn.stream.as_raw_fd());
            self.live_conns.fetch_sub(1, Ordering::SeqCst);
            self.metrics.record_close(conn.stats);
        }
        // closing the dispatch channel ends the dispatcher
        drop(self.dispatch_tx.take());
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
    }

    /// One `GoAway{reason, grace_ms}` per live v2 connection, sent the
    /// moment this reactor observes the drain flag. v1 peers have no
    /// GoAway in their grammar — they see `ShutDown` errors on their
    /// next request instead.
    fn broadcast_goaway(&self, poller: &Poller, conns: &mut BTreeMap<u64, Conn>) {
        let reason =
            self.drain_reason.lock().unwrap_or_else(|e| e.into_inner()).clone();
        for conn in conns.values_mut() {
            if conn.dead || conn.closing || conn.peer_version < 2 {
                continue;
            }
            encode_frame(
                &Frame::GoAway(GoAway {
                    grace_ms: self.opts.drain_grace_ms,
                    reason: reason.clone(),
                }),
                &mut conn.out,
            );
            self.metrics.record_frame_out();
            self.metrics.record_goaway();
            conn.stats.frames_out += 1;
            Self::flush(&self.metrics, conn);
            Self::update_interest(poller, conn);
        }
    }

    fn drain_wake(&self) {
        let mut buf = [0u8; 64];
        loop {
            match (&self.wake_rx).read(&mut buf) {
                Ok(0) => break,
                Ok(_) => continue,
                Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => break,
            }
        }
    }

    fn apply_completions(&self, poller: &Poller, conns: &mut BTreeMap<u64, Conn>) {
        let done: Vec<Completion> = {
            let mut q = self.completions.lock().unwrap_or_else(|e| e.into_inner());
            q.drain(..).collect()
        };
        for c in done {
            // the conn may have died while its rows were in flight; the
            // verdicts are already accounted, only the bytes are dropped
            if let Some(conn) = conns.get_mut(&c.token) {
                conn.in_flight -= 1;
                conn.out.extend_from_slice(&c.bytes);
                conn.stats.frames_out += 1;
                Self::flush(&self.metrics, conn);
                Self::update_interest(poller, conn);
            }
        }
    }

    fn accept_all(
        &self,
        poller: &Poller,
        conns: &mut BTreeMap<u64, Conn>,
        next_token: &mut u64,
    ) {
        loop {
            match self.listener.accept() {
                Ok((stream, peer)) => {
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    if self.opts.nodelay {
                        let _ = stream.set_nodelay(true);
                    }
                    let token = *next_token;
                    *next_token += 1;
                    if poller.add(stream.as_raw_fd(), token, true, false).is_err() {
                        continue;
                    }
                    self.metrics.record_accept();
                    let prev = self.live_conns.fetch_add(1, Ordering::SeqCst);
                    let over_cap = self.opts.max_conns > 0 && prev >= self.opts.max_conns;
                    let mk_bucket = |rate: u64| {
                        (rate > 0).then(|| TokenBucket::new(rate.max(1), rate as f64))
                    };
                    let mut conn = Conn {
                        stream,
                        token,
                        deframer: Deframer::new(self.opts.max_frame_bytes),
                        out: Vec::new(),
                        out_pos: 0,
                        want_write: false,
                        want_read: true,
                        in_flight: 0,
                        closing: false,
                        peer_eof: false,
                        dead: false,
                        peer_version: 1,
                        client_id: 0,
                        authed: self.opts.auth_token.is_none(),
                        frame_bucket: mk_bucket(self.opts.frame_rate_limit),
                        row_bucket: mk_bucket(self.opts.row_rate_limit),
                        stats: ConnIngress {
                            id: token,
                            peer: peer.to_string(),
                            ..ConnIngress::default()
                        },
                    };
                    if over_cap {
                        self.metrics.record_conn_refused();
                        Self::queue_error(
                            &self.metrics,
                            &mut conn,
                            Status::TooManyConnections,
                            "connection cap reached; retry against another replica",
                        );
                        conn.closing = true;
                    }
                    conns.insert(token, conn);
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => break,
            }
        }
    }

    fn handle_readable(&self, conn: &mut Conn, draining: bool) {
        if conn.closing || conn.peer_eof {
            return;
        }
        let mut buf = [0u8; READ_CHUNK];
        loop {
            match conn.stream.read(&mut buf) {
                Ok(0) => {
                    conn.peer_eof = true;
                    break;
                }
                Ok(n) => {
                    self.metrics.record_bytes_in(n as u64);
                    conn.stats.bytes_in += n as u64;
                    conn.deframer.extend(&buf[..n]);
                    self.process_frames(conn, draining);
                    if conn.closing {
                        break;
                    }
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    conn.dead = true;
                    break;
                }
            }
        }
    }

    fn process_frames(&self, conn: &mut Conn, draining: bool) {
        loop {
            let payload = match conn.deframer.next_payload() {
                Ok(Some(p)) => p,
                Ok(None) => break,
                Err(e) => {
                    self.protocol_error(conn, &e.to_string());
                    break;
                }
            };
            match decode_payload_versioned(&payload) {
                Ok((version, frame)) => {
                    conn.peer_version = conn.peer_version.max(version);
                    match frame {
                        Frame::Request(req) => self.handle_request(conn, req, draining),
                        Frame::Hello(h) => self.handle_hello(conn, h),
                        _ => {
                            self.protocol_error(
                                conn,
                                "only request and hello frames flow client -> server",
                            );
                        }
                    }
                }
                Err(e) => self.protocol_error(conn, &e.to_string()),
            }
            if conn.closing {
                break;
            }
        }
    }

    /// The auth gate: a `Hello` carries the client's id (replay-cache
    /// namespace) and, when the server demands one, the shared-secret
    /// token. A wrong token fails the connection closed; without a
    /// configured token every `Hello` is accepted silently.
    fn handle_hello(&self, conn: &mut Conn, hello: super::proto::Hello) {
        self.metrics.record_frame_in();
        conn.stats.frames_in += 1;
        conn.client_id = hello.client_id;
        match &self.opts.auth_token {
            Some(expected) if hello.token != *expected => {
                self.metrics.record_auth_failure();
                Self::queue_error(
                    &self.metrics,
                    conn,
                    Status::AuthFailed,
                    "auth token rejected",
                );
                conn.closing = true;
            }
            _ => conn.authed = true,
        }
    }

    fn handle_request(&self, conn: &mut Conn, req: InferRequest, draining: bool) {
        let rows = req.rows() as u64;
        self.metrics.record_frame_in();
        conn.stats.frames_in += 1;
        conn.stats.rows_in += rows;

        if draining {
            self.metrics.record_drain_refused(rows);
            Self::queue_error(&self.metrics, conn, Status::ShutDown, "server is draining");
            return;
        }
        if !conn.authed {
            self.metrics.record_auth_failure();
            Self::queue_error(
                &self.metrics,
                conn,
                Status::AuthFailed,
                "auth required: send a hello frame with the shared token first",
            );
            conn.closing = true;
            return;
        }
        let mut limited = false;
        if let Some(b) = conn.frame_bucket.as_mut() {
            limited |= !b.take_now(1);
        }
        if !limited {
            if let Some(b) = conn.row_bucket.as_mut() {
                limited |= !b.take_now(rows);
            }
        }
        if limited {
            self.metrics.record_rate_limited(&req.model, rows);
            Self::queue_error(
                &self.metrics,
                conn,
                Status::RateLimited,
                "per-connection rate limit exceeded; retry later",
            );
            return;
        }
        let keyed = req.key != 0 && conn.client_id != 0;
        if keyed {
            let id = (conn.client_id, req.key);
            match lock_replay(&self.replay).state(id) {
                ReplayState::Done(bytes, cached_rows) => {
                    // the original verdicts, replayed byte-for-byte:
                    // nothing is re-submitted, nothing double-counts
                    self.metrics.record_replay(cached_rows);
                    self.metrics.record_frame_out();
                    conn.out.extend_from_slice(&bytes);
                    conn.stats.frames_out += 1;
                    Self::flush(&self.metrics, conn);
                    return;
                }
                ReplayState::Pending => {
                    // the first submission of this key is still in
                    // flight; admitting a second would double-submit
                    self.metrics.record_admission_rejected(&req.model, rows);
                    Self::queue_error(
                        &self.metrics,
                        conn,
                        Status::AdmissionRejected,
                        "idempotency key still in flight; retry shortly",
                    );
                    return;
                }
                ReplayState::New => {}
            }
        }
        let client = match self.fleet.client(&req.model) {
            Ok(c) => c,
            Err(_) => {
                self.metrics.record_unknown_model(rows);
                Self::queue_error(
                    &self.metrics,
                    conn,
                    Status::UnknownModel,
                    &format!("no model '{}' is registered", req.model),
                );
                return;
            }
        };
        if !self.admission.try_admit(&req.model, rows) {
            self.metrics.record_admission_rejected(&req.model, rows);
            Self::queue_error(
                &self.metrics,
                conn,
                Status::AdmissionRejected,
                "shared admission budget exhausted; retry later",
            );
            return;
        }
        self.metrics.record_admitted(&req.model, rows);
        if keyed {
            lock_replay(&self.replay).begin((conn.client_id, req.key));
        }
        conn.in_flight += 1;
        let dispatch = Dispatch {
            token: conn.token,
            key: req.key,
            client_id: conn.client_id,
            peer_version: conn.peer_version,
            model: req.model,
            features: req.features as usize,
            data: req.data,
            client,
            t0: Instant::now(),
        };
        let lost = match &self.dispatch_tx {
            Some(tx) => match tx.send(dispatch) {
                Ok(()) => return,
                Err(std::sync::mpsc::SendError(d)) => d,
            },
            None => dispatch,
        };
        // dispatcher gone (only during teardown): undo the admit and
        // answer every admitted row with a ShutDown verdict so the
        // wire accounting still balances exactly
        conn.in_flight -= 1;
        if lost.key != 0 && lost.client_id != 0 {
            lock_replay(&self.replay).abort((lost.client_id, lost.key));
        }
        self.admission.release(&lost.model, rows);
        let mut out_rows = Vec::with_capacity(rows as usize);
        for _ in 0..rows {
            self.metrics.record_row_verdict(&lost.model, Status::ShutDown);
            out_rows.push(RowReply::error(Status::ShutDown));
        }
        encode_frame_at(
            &Frame::Reply(InferReply { key: lost.key, rows: out_rows }),
            conn.peer_version,
            &mut conn.out,
        );
        self.metrics.record_frame_out();
        conn.stats.frames_out += 1;
        Self::flush(&self.metrics, conn);
    }

    fn protocol_error(&self, conn: &mut Conn, detail: &str) {
        self.metrics.record_protocol_error();
        conn.stats.protocol_error = true;
        Self::queue_error(&self.metrics, conn, Status::Malformed, detail);
        conn.closing = true; // fail closed once the error frame flushes
    }

    fn queue_error(metrics: &NetMetrics, conn: &mut Conn, status: Status, message: &str) {
        let frame = Frame::Error(ErrorReply { status, message: message.to_string() });
        // mirror the peer's version so v1 clients keep decoding
        encode_frame_at(&frame, conn.peer_version, &mut conn.out);
        metrics.record_frame_out();
        conn.stats.frames_out += 1;
        Self::flush(metrics, conn);
    }

    fn flush(metrics: &NetMetrics, conn: &mut Conn) {
        while conn.out_pos < conn.out.len() {
            match conn.stream.write(&conn.out[conn.out_pos..]) {
                Ok(0) => {
                    conn.dead = true;
                    break;
                }
                Ok(n) => {
                    conn.out_pos += n;
                    metrics.record_bytes_out(n as u64);
                    conn.stats.bytes_out += n as u64;
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    conn.dead = true;
                    break;
                }
            }
        }
        if conn.flushed() {
            conn.out.clear();
            conn.out_pos = 0;
        }
    }

    fn update_interest(poller: &Poller, conn: &mut Conn) {
        let want_read = !(conn.peer_eof || conn.closing || conn.dead);
        let want_write = !conn.flushed() && !conn.dead;
        if want_read != conn.want_read || want_write != conn.want_write {
            let _ =
                poller.modify(conn.stream.as_raw_fd(), conn.token, want_read, want_write);
            conn.want_read = want_read;
            conn.want_write = want_write;
        }
    }
}
