//! Thread-per-core socket serving: nonblocking accept + readiness
//! polling ([`Poller`]) on N reactor threads, each owning its accepted
//! connections end-to-end. A reactor parses frames, runs admission,
//! and hands whole request frames to its paired dispatcher thread,
//! which submits every row into the existing
//! [`FleetClient`](crate::coordinator::registry::FleetClient) path —
//! so hot swaps, deadlines, load shedding, panic isolation and the
//! exact accounting invariant all hold unchanged for socket traffic.
//!
//! ```text
//!                 ┌───────────────┐  frames   ┌──────────────────┐
//!  conns ──────▶  │ net-reactor-k │ ────────▶ │ net-dispatch-k   │
//!  (epoll/kqueue) │ parse+admit   │ ◀──────── │ submit rows into │
//!                 └───────────────┘  replies  │ FleetClient      │
//!                                             └──────────────────┘
//! ```
//!
//! Ordering contract: replies on one connection come back in request
//! order (one dispatcher per reactor, frames processed FIFO, rows
//! inside a frame kept in submit order). A dispatcher blocking on one
//! slow frame delays other frames of the *same reactor* only; scale
//! `--net-threads` to isolate tenants.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Mutex};

use crate::coordinator::registry::FleetClient;
use crate::coordinator::Client;

use super::admission::AdmissionController;
use super::metrics::{ConnIngress, NetMetrics, NetSnapshot};
use super::poll::Poller;
use super::proto::{
    decode_payload, encode_frame, Deframer, ErrorReply, Frame, InferReply, InferRequest,
    RowReply, Status, MAX_FRAME_BYTES,
};

const TOKEN_LISTENER: u64 = 0;
const TOKEN_WAKE: u64 = 1;
const TOKEN_BASE: u64 = 2;
const READ_CHUNK: usize = 16 * 1024;

/// Tuning knobs for [`NetServer::start`].
#[derive(Debug, Clone)]
pub struct NetServerOptions {
    /// Reactor thread count; `0` = one per available core.
    pub threads: usize,
    /// Per-frame payload cap (default [`MAX_FRAME_BYTES`]).
    pub max_frame_bytes: usize,
    /// Set `TCP_NODELAY` on accepted connections.
    pub nodelay: bool,
}

impl Default for NetServerOptions {
    fn default() -> Self {
        NetServerOptions { threads: 0, max_frame_bytes: MAX_FRAME_BYTES, nodelay: true }
    }
}

/// Wakes a reactor out of `Poller::wait` (self-pipe).
struct Waker {
    pipe: UnixStream,
}

impl Waker {
    fn wake(&self) {
        // a full pipe already guarantees a pending wakeup
        let _ = (&self.pipe).write(&[1u8]);
    }
}

/// One frame handed from a reactor to its dispatcher.
struct Dispatch {
    token: u64,
    model: String,
    features: usize,
    data: Vec<f32>,
    client: Client,
}

/// One encoded reply travelling back from a dispatcher to its reactor.
struct Completion {
    token: u64,
    bytes: Vec<u8>,
}

struct ReactorHandle {
    waker: Arc<Waker>,
    join: std::thread::JoinHandle<()>,
}

/// A running socket serving tier. Dropping it (or calling
/// [`shutdown`](NetServer::shutdown)) drains in-flight requests,
/// answers anything newly arrived with a typed `ShuttingDown` error,
/// flushes and joins every thread.
pub struct NetServer {
    local_addr: SocketAddr,
    threads: usize,
    shutdown: Arc<AtomicBool>,
    reactors: Vec<ReactorHandle>,
    metrics: Arc<NetMetrics>,
    admission: Arc<AdmissionController>,
}

impl NetServer {
    /// Bind `addr` and start serving `fleet` behind `admission`.
    pub fn start(
        addr: &str,
        fleet: FleetClient,
        admission: Arc<AdmissionController>,
        opts: NetServerOptions,
    ) -> std::io::Result<NetServer> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local_addr = listener.local_addr()?;
        // fail at start, not inside a thread, where no poll backend exists
        drop(Poller::new()?);

        let threads = if opts.threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            opts.threads
        }
        .clamp(1, 64);

        let metrics = NetMetrics::new();
        let shutdown = Arc::new(AtomicBool::new(false));
        let mut reactors = Vec::with_capacity(threads);
        for i in 0..threads {
            let (wake_tx, wake_rx) = UnixStream::pair()?;
            wake_tx.set_nonblocking(true)?;
            wake_rx.set_nonblocking(true)?;
            let waker = Arc::new(Waker { pipe: wake_tx });
            let completions: Arc<Mutex<Vec<Completion>>> = Arc::new(Mutex::new(Vec::new()));
            let (dispatch_tx, dispatch_rx) = std::sync::mpsc::channel::<Dispatch>();

            let dispatcher = {
                let admission = admission.clone();
                let metrics = metrics.clone();
                let completions = completions.clone();
                let waker = waker.clone();
                std::thread::Builder::new()
                    .name(format!("net-dispatch-{i}"))
                    .spawn(move || {
                        dispatcher_loop(dispatch_rx, admission, metrics, completions, waker)
                    })?
            };

            let reactor = Reactor {
                listener: listener.try_clone()?,
                wake_rx,
                dispatch_tx: Some(dispatch_tx),
                dispatcher: Some(dispatcher),
                completions,
                shutdown: shutdown.clone(),
                metrics: metrics.clone(),
                admission: admission.clone(),
                fleet: fleet.clone(),
                opts: opts.clone(),
            };
            let join = std::thread::Builder::new()
                .name(format!("net-reactor-{i}"))
                .spawn(move || reactor.run())?;
            reactors.push(ReactorHandle { waker, join });
        }

        Ok(NetServer { local_addr, threads, shutdown, reactors, metrics, admission })
    }

    /// The bound address (resolves `:0` to the chosen port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Reactor thread count actually running.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Live ingress counters (shared with the reactors).
    pub fn metrics(&self) -> &Arc<NetMetrics> {
        &self.metrics
    }

    /// Total rows answered over the wire so far.
    pub fn rows_done(&self) -> u64 {
        self.metrics.rows_done()
    }

    /// Point-in-time ingress snapshot without stopping the server.
    pub fn snapshot(&self) -> NetSnapshot {
        self.metrics.snapshot(self.admission.snapshot())
    }

    fn stop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        for r in &self.reactors {
            r.waker.wake();
        }
        for r in self.reactors.drain(..) {
            let _ = r.join.join();
        }
    }

    /// Drain, stop every thread and return the final ingress snapshot.
    pub fn shutdown(mut self) -> NetSnapshot {
        self.stop();
        self.metrics.snapshot(self.admission.snapshot())
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.stop();
    }
}

// ---- dispatcher -----------------------------------------------------------

fn dispatcher_loop(
    rx: Receiver<Dispatch>,
    admission: Arc<AdmissionController>,
    metrics: Arc<NetMetrics>,
    completions: Arc<Mutex<Vec<Completion>>>,
    waker: Arc<Waker>,
) {
    while let Ok(d) = rx.recv() {
        let rows = d.data.len() / d.features.max(1);
        // submit every row before waiting on any: rows of one frame
        // land in the ingress queue together and batch together
        let mut pendings = Vec::with_capacity(rows);
        for row in d.data.chunks_exact(d.features) {
            pendings.push(d.client.submit(row.to_vec()));
        }
        let mut out_rows = Vec::with_capacity(rows);
        for p in pendings {
            let verdict = match p {
                Ok(pending) => pending.wait(),
                Err(e) => Err(e),
            };
            let row = match verdict {
                Ok(resp) => RowReply {
                    status: Status::Ok,
                    class: resp.class.min(u16::MAX as usize) as u16,
                    version: resp.version,
                    logits: resp.logits,
                },
                Err(e) => RowReply::error(Status::from_serve_error(&e)),
            };
            metrics.record_row_verdict(&d.model, row.status);
            out_rows.push(row);
        }
        admission.release(&d.model, rows as u64);

        let mut bytes = Vec::new();
        encode_frame(&Frame::Reply(InferReply { rows: out_rows }), &mut bytes);
        metrics.record_frame_out();
        completions.lock().unwrap_or_else(|e| e.into_inner()).push(Completion {
            token: d.token,
            bytes,
        });
        waker.wake();
    }
}

// ---- reactor --------------------------------------------------------------

struct Conn {
    stream: TcpStream,
    token: u64,
    deframer: Deframer,
    out: Vec<u8>,
    out_pos: usize,
    want_write: bool,
    want_read: bool,
    in_flight: usize,
    closing: bool,
    peer_eof: bool,
    dead: bool,
    stats: ConnIngress,
}

impl Conn {
    fn flushed(&self) -> bool {
        self.out_pos >= self.out.len()
    }

    fn finished(&self, draining: bool) -> bool {
        if self.dead {
            return true;
        }
        if !self.flushed() {
            return false;
        }
        if self.closing {
            return true;
        }
        (self.peer_eof || draining) && self.in_flight == 0
    }
}

struct Reactor {
    listener: TcpListener,
    wake_rx: UnixStream,
    dispatch_tx: Option<Sender<Dispatch>>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
    completions: Arc<Mutex<Vec<Completion>>>,
    shutdown: Arc<AtomicBool>,
    metrics: Arc<NetMetrics>,
    admission: Arc<AdmissionController>,
    fleet: FleetClient,
    opts: NetServerOptions,
}

impl Reactor {
    fn run(mut self) {
        let poller = match Poller::new() {
            Ok(p) => p,
            Err(_) => return, // probed at start; cannot happen here
        };
        if poller.add(self.listener.as_raw_fd(), TOKEN_LISTENER, true, false).is_err() {
            return;
        }
        if poller.add(self.wake_rx.as_raw_fd(), TOKEN_WAKE, true, false).is_err() {
            return;
        }

        let mut conns: BTreeMap<u64, Conn> = BTreeMap::new();
        let mut next_token = TOKEN_BASE;
        let mut events = Vec::with_capacity(128);
        let mut listener_armed = true;

        loop {
            let draining = self.shutdown.load(Ordering::SeqCst);
            if draining && listener_armed {
                let _ = poller.delete(self.listener.as_raw_fd());
                listener_armed = false;
            }
            if draining && conns.is_empty() {
                break;
            }

            events.clear();
            // the waker covers completions and shutdown; the timeout is
            // a belt-and-braces bound so a lost wakeup can only stall,
            // never hang, the drain
            if poller.wait(&mut events, 100).is_err() {
                break;
            }

            self.drain_wake();
            self.apply_completions(&poller, &mut conns);

            for k in 0..events.len() {
                let ev = events[k];
                match ev.token {
                    TOKEN_LISTENER => {
                        if !draining {
                            self.accept_all(&poller, &mut conns, &mut next_token);
                        }
                    }
                    TOKEN_WAKE => self.drain_wake(),
                    token => {
                        if let Some(conn) = conns.get_mut(&token) {
                            if ev.readable {
                                self.handle_readable(conn, draining);
                            }
                            if ev.writable {
                                Self::flush(&self.metrics, conn);
                            }
                            Self::update_interest(&poller, conn);
                        }
                    }
                }
            }

            let done: Vec<u64> =
                conns.values().filter(|c| c.finished(draining)).map(|c| c.token).collect();
            for token in done {
                if let Some(conn) = conns.remove(&token) {
                    let _ = poller.delete(conn.stream.as_raw_fd());
                    self.metrics.record_close(conn.stats);
                }
            }
        }

        for (_, conn) in conns {
            let _ = poller.delete(conn.stream.as_raw_fd());
            self.metrics.record_close(conn.stats);
        }
        // closing the dispatch channel ends the dispatcher
        drop(self.dispatch_tx.take());
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
    }

    fn drain_wake(&self) {
        let mut buf = [0u8; 64];
        loop {
            match (&self.wake_rx).read(&mut buf) {
                Ok(0) => break,
                Ok(_) => continue,
                Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => break,
            }
        }
    }

    fn apply_completions(&self, poller: &Poller, conns: &mut BTreeMap<u64, Conn>) {
        let done: Vec<Completion> = {
            let mut q = self.completions.lock().unwrap_or_else(|e| e.into_inner());
            q.drain(..).collect()
        };
        for c in done {
            // the conn may have died while its rows were in flight; the
            // verdicts are already accounted, only the bytes are dropped
            if let Some(conn) = conns.get_mut(&c.token) {
                conn.in_flight -= 1;
                conn.out.extend_from_slice(&c.bytes);
                Self::flush(&self.metrics, conn);
                Self::update_interest(poller, conn);
            }
        }
    }

    fn accept_all(
        &self,
        poller: &Poller,
        conns: &mut BTreeMap<u64, Conn>,
        next_token: &mut u64,
    ) {
        loop {
            match self.listener.accept() {
                Ok((stream, peer)) => {
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    if self.opts.nodelay {
                        let _ = stream.set_nodelay(true);
                    }
                    let token = *next_token;
                    *next_token += 1;
                    if poller.add(stream.as_raw_fd(), token, true, false).is_err() {
                        continue;
                    }
                    self.metrics.record_accept();
                    conns.insert(
                        token,
                        Conn {
                            stream,
                            token,
                            deframer: Deframer::new(self.opts.max_frame_bytes),
                            out: Vec::new(),
                            out_pos: 0,
                            want_write: false,
                            want_read: true,
                            in_flight: 0,
                            closing: false,
                            peer_eof: false,
                            dead: false,
                            stats: ConnIngress {
                                id: token,
                                peer: peer.to_string(),
                                ..ConnIngress::default()
                            },
                        },
                    );
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => break,
            }
        }
    }

    fn handle_readable(&self, conn: &mut Conn, draining: bool) {
        if conn.closing || conn.peer_eof {
            return;
        }
        let mut buf = [0u8; READ_CHUNK];
        loop {
            match conn.stream.read(&mut buf) {
                Ok(0) => {
                    conn.peer_eof = true;
                    break;
                }
                Ok(n) => {
                    self.metrics.record_bytes_in(n as u64);
                    conn.stats.bytes_in += n as u64;
                    conn.deframer.extend(&buf[..n]);
                    self.process_frames(conn, draining);
                    if conn.closing {
                        break;
                    }
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    conn.dead = true;
                    break;
                }
            }
        }
    }

    fn process_frames(&self, conn: &mut Conn, draining: bool) {
        loop {
            let payload = match conn.deframer.next_payload() {
                Ok(Some(p)) => p,
                Ok(None) => break,
                Err(e) => {
                    self.protocol_error(conn, &e.to_string());
                    break;
                }
            };
            match decode_payload(&payload) {
                Ok(Frame::Request(req)) => self.handle_request(conn, req, draining),
                Ok(_) => {
                    self.protocol_error(conn, "only request frames flow client -> server");
                }
                Err(e) => self.protocol_error(conn, &e.to_string()),
            }
            if conn.closing {
                break;
            }
        }
    }

    fn handle_request(&self, conn: &mut Conn, req: InferRequest, draining: bool) {
        let rows = req.rows() as u64;
        self.metrics.record_frame_in();
        conn.stats.frames_in += 1;
        conn.stats.rows_in += rows;

        if draining {
            self.metrics.record_drain_refused(rows);
            Self::queue_error(&self.metrics, conn, Status::ShutDown, "server is draining");
            return;
        }
        let client = match self.fleet.client(&req.model) {
            Ok(c) => c,
            Err(_) => {
                self.metrics.record_unknown_model(rows);
                Self::queue_error(
                    &self.metrics,
                    conn,
                    Status::UnknownModel,
                    &format!("no model '{}' is registered", req.model),
                );
                return;
            }
        };
        if !self.admission.try_admit(&req.model, rows) {
            self.metrics.record_admission_rejected(&req.model, rows);
            Self::queue_error(
                &self.metrics,
                conn,
                Status::AdmissionRejected,
                "shared admission budget exhausted; retry later",
            );
            return;
        }
        self.metrics.record_admitted(&req.model, rows);
        conn.in_flight += 1;
        let dispatch = Dispatch {
            token: conn.token,
            model: req.model,
            features: req.features as usize,
            data: req.data,
            client,
        };
        let lost = match &self.dispatch_tx {
            Some(tx) => match tx.send(dispatch) {
                Ok(()) => return,
                Err(std::sync::mpsc::SendError(d)) => d,
            },
            None => dispatch,
        };
        // dispatcher gone (only during teardown): undo the admit and
        // answer every admitted row with a ShutDown verdict so the
        // wire accounting still balances exactly
        conn.in_flight -= 1;
        self.admission.release(&lost.model, rows);
        let mut out_rows = Vec::with_capacity(rows as usize);
        for _ in 0..rows {
            self.metrics.record_row_verdict(&lost.model, Status::ShutDown);
            out_rows.push(RowReply::error(Status::ShutDown));
        }
        encode_frame(&Frame::Reply(InferReply { rows: out_rows }), &mut conn.out);
        self.metrics.record_frame_out();
        Self::flush(&self.metrics, conn);
    }

    fn protocol_error(&self, conn: &mut Conn, detail: &str) {
        self.metrics.record_protocol_error();
        conn.stats.protocol_error = true;
        Self::queue_error(&self.metrics, conn, Status::Malformed, detail);
        conn.closing = true; // fail closed once the error frame flushes
    }

    fn queue_error(metrics: &NetMetrics, conn: &mut Conn, status: Status, message: &str) {
        let frame = Frame::Error(ErrorReply { status, message: message.to_string() });
        encode_frame(&frame, &mut conn.out);
        metrics.record_frame_out();
        Self::flush(metrics, conn);
    }

    fn flush(metrics: &NetMetrics, conn: &mut Conn) {
        while conn.out_pos < conn.out.len() {
            match conn.stream.write(&conn.out[conn.out_pos..]) {
                Ok(0) => {
                    conn.dead = true;
                    break;
                }
                Ok(n) => {
                    conn.out_pos += n;
                    metrics.record_bytes_out(n as u64);
                    conn.stats.bytes_out += n as u64;
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    conn.dead = true;
                    break;
                }
            }
        }
        if conn.flushed() {
            conn.out.clear();
            conn.out_pos = 0;
        }
    }

    fn update_interest(poller: &Poller, conn: &mut Conn) {
        let want_read = !(conn.peer_eof || conn.closing || conn.dead);
        let want_write = !conn.flushed() && !conn.dead;
        if want_read != conn.want_read || want_write != conn.want_write {
            let _ =
                poller.modify(conn.stream.as_raw_fd(), conn.token, want_read, want_write);
            conn.want_read = want_read;
            conn.want_write = want_write;
        }
    }
}
