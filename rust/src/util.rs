//! Small shared utilities: deterministic PRNG, byte-size formatting,
//! simple statistics. No external dependencies so the whole substrate is
//! reproducible bit-for-bit across runs.

/// xoshiro256** — deterministic, fast, no deps. Used for dataset
/// synthesis, weight init and stochastic rounding dither sequences.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that nearby seeds give unrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Fisher–Yates shuffle of indices 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
        v
    }
}

/// Format a bit count the way the paper does (ISO/IEC 80000 binary
/// prefixes over *bytes*): "17.50 MiB", "30.60 KiB", "12.26 GiB".
pub fn fmt_bits(bits: u64) -> String {
    fmt_bytes(bits as f64 / 8.0)
}

/// Format a byte count with binary prefixes (up to EiB; the planner can
/// emit astronomically large whole-code configs that the paper itself
/// only quotes to dismiss).
pub fn fmt_bytes(bytes: f64) -> String {
    const KIB: f64 = 1024.0;
    const MIB: f64 = KIB * 1024.0;
    const GIB: f64 = MIB * 1024.0;
    const TIB: f64 = GIB * 1024.0;
    const PIB: f64 = TIB * 1024.0;
    const EIB: f64 = PIB * 1024.0;
    if bytes >= EIB {
        format!(">= {:.0} EiB", bytes / EIB)
    } else if bytes >= PIB {
        format!("{:.2} PiB", bytes / PIB)
    } else if bytes >= TIB {
        format!("{:.2} TiB", bytes / TIB)
    } else if bytes >= GIB {
        format!("{:.2} GiB", bytes / GIB)
    } else if bytes >= MIB {
        format!("{:.2} MiB", bytes / MIB)
    } else if bytes >= KIB {
        format!("{:.2} KiB", bytes / KIB)
    } else {
        format!("{:.0} B", bytes)
    }
}

/// Format a large op count compactly: 12.90M, 23.5K, 1650.
pub fn fmt_ops(ops: u64) -> String {
    if ops >= 1_000_000 {
        format!("{:.2}M", ops as f64 / 1e6)
    } else if ops >= 10_000 {
        format!("{:.1}K", ops as f64 / 1e3)
    } else {
        format!("{ops}")
    }
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile (nearest-rank) of an unsorted slice; p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// ceil(log2(n)) for n >= 1 — the paper's β(I) = ⌈log2 |I|⌉.
pub fn ceil_log2(n: u64) -> u32 {
    assert!(n >= 1, "ceil_log2 of zero");
    64 - (n - 1).leading_zeros()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_streams_differ_across_seeds() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn rng_f32_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn rng_below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn rng_normal_moments() {
        let mut r = Rng::new(3);
        let xs: Vec<f64> = (0..50_000).map(|_| r.normal() as f64).collect();
        assert!(mean(&xs).abs() < 0.02);
        assert!((stddev(&xs) - 1.0).abs() < 0.02);
    }

    #[test]
    fn permutation_is_a_permutation() {
        let mut r = Rng::new(5);
        let mut p = r.permutation(100);
        p.sort_unstable();
        assert_eq!(p, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fmt_bits_matches_paper_style() {
        let bits = (17.5 * 1024.0 * 1024.0 * 8.0) as u64;
        assert_eq!(fmt_bits(bits), "17.50 MiB");
        assert_eq!(fmt_bytes(31334.4), "30.60 KiB");
    }

    #[test]
    fn fmt_ops_style() {
        assert_eq!(fmt_ops(1650), "1650");
        assert_eq!(fmt_ops(12_900_000), "12.90M");
        assert_eq!(fmt_ops(23_520), "23.5K");
    }

    #[test]
    fn ceil_log2_values() {
        assert_eq!(ceil_log2(1), 0);
        assert_eq!(ceil_log2(2), 1);
        assert_eq!(ceil_log2(3), 2);
        assert_eq!(ceil_log2(256), 8);
        assert_eq!(ceil_log2(257), 9);
    }

    #[test]
    fn percentile_basics() {
        let xs = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }
}
