//! Number-format substrate: fixed-point Q formats, an IEEE 754 binary16
//! codec written from scratch, an 8-bit minifloat, and LUT-based
//! stochastic rounding — everything the paper's input sets `I` need.
//!
//! The paper's LUT is indexed by *bit patterns*; these modules own the
//! mapping between `f32` values and those patterns, so the `lut` and
//! `engine` layers can stay purely integer.

pub mod f16;
pub mod minifloat;
pub mod stochastic;

/// Unsigned fixed-point format with `bits` total bits, all fractional:
/// code `c` represents `c / 2^bits`, covering [0, 1). This is the format
/// the paper uses for image inputs ("8-bits in fixed point format to
/// encode the input images", "input quantized to 3 bits").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FixedFormat {
    /// Total bits per element (the paper's r_I).
    pub bits: u32,
}

impl FixedFormat {
    pub fn new(bits: u32) -> Self {
        assert!((1..=16).contains(&bits), "fixed format bits in 1..=16");
        FixedFormat { bits }
    }

    /// Number of representable codes.
    pub fn levels(&self) -> u32 {
        1 << self.bits
    }

    /// Quantize a value in [0, 1] to its code (floor, saturating).
    /// `as u32` truncates toward zero == floor for non-negatives, and
    /// saturates NaN to 0 — one multiply + cast + min on the hot path.
    #[inline]
    pub fn quantize(&self, x: f32) -> u32 {
        let v = (x.max(0.0) * self.levels() as f32) as u32;
        v.min(self.levels() - 1)
    }

    /// Dequantize a code back to f32 (mid-tread: c / 2^bits).
    #[inline]
    pub fn dequantize(&self, code: u32) -> f32 {
        code as f32 / self.levels() as f32
    }

    /// Quantize-dequantize (the fake-quant op inserted before LUT-fed
    /// layers during training).
    #[inline]
    pub fn fake_quant(&self, x: f32) -> f32 {
        self.dequantize(self.quantize(x))
    }

    /// Extract bitplane `j` (0 = LSB) of the code for value x.
    #[inline]
    pub fn bitplane(&self, x: f32, j: u32) -> u32 {
        debug_assert!(j < self.bits);
        (self.quantize(x) >> j) & 1
    }
}

/// Signed two's-complement fixed-point: `bits` total, MSB is the sign
/// bit, remaining bits fractional over [-1, 1). Used by the signed-LUT
/// path (paper §Dealing with signed numbers, Fig. 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SignedFixedFormat {
    pub bits: u32,
}

impl SignedFixedFormat {
    pub fn new(bits: u32) -> Self {
        assert!((2..=16).contains(&bits), "signed fixed bits in 2..=16");
        SignedFixedFormat { bits }
    }

    /// Quantize x in [-1, 1) to an n-bit two's-complement code
    /// (returned in the low `bits` bits of the u32).
    #[inline]
    pub fn quantize(&self, x: f32) -> u32 {
        let half = (1u32 << (self.bits - 1)) as f32;
        let v = (x * half).floor().clamp(-half, half - 1.0) as i32;
        (v as u32) & ((1 << self.bits) - 1)
    }

    /// Dequantize a two's-complement code back to f32.
    #[inline]
    pub fn dequantize(&self, code: u32) -> f32 {
        let n = self.bits;
        let raw = code & ((1 << n) - 1);
        let signed = if raw >> (n - 1) == 1 {
            raw as i64 - (1i64 << n)
        } else {
            raw as i64
        };
        signed as f32 / (1u32 << (n - 1)) as f32
    }

    /// The magnitude bits x_b (code minus the MSB) — the paper's
    /// "bitstring x minus the MSB".
    #[inline]
    pub fn magnitude_bits(&self, code: u32) -> u32 {
        code & ((1 << (self.bits - 1)) - 1)
    }

    /// The sign (MSB) bit.
    #[inline]
    pub fn msb(&self, code: u32) -> u32 {
        (code >> (self.bits - 1)) & 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_quant_roundtrip_monotone() {
        let f = FixedFormat::new(3);
        assert_eq!(f.levels(), 8);
        let mut last = 0;
        for i in 0..=100 {
            let x = i as f32 / 100.0;
            let c = f.quantize(x);
            assert!(c >= last, "quantize must be monotone");
            assert!(c < 8);
            last = c;
        }
    }

    #[test]
    fn fixed_quant_error_bounded() {
        let f = FixedFormat::new(8);
        for i in 0..1000 {
            let x = i as f32 / 1000.0;
            let err = (f.fake_quant(x) - x).abs();
            assert!(err <= 1.0 / 256.0 + 1e-6, "err {err} at {x}");
        }
    }

    #[test]
    fn fixed_quant_saturates() {
        let f = FixedFormat::new(4);
        assert_eq!(f.quantize(2.0), 15);
        assert_eq!(f.quantize(-1.0), 0);
        assert_eq!(f.quantize(1.0), 15);
    }

    #[test]
    fn bitplanes_reassemble_code() {
        let f = FixedFormat::new(5);
        for i in 0..100 {
            let x = i as f32 / 100.0;
            let code = f.quantize(x);
            let rebuilt: u32 = (0..5).map(|j| f.bitplane(x, j) << j).sum();
            assert_eq!(rebuilt, code);
        }
    }

    #[test]
    fn signed_roundtrip() {
        let f = SignedFixedFormat::new(8);
        for i in -100..100 {
            let x = i as f32 / 101.0;
            let c = f.quantize(x);
            let y = f.dequantize(c);
            assert!((x - y).abs() <= 1.0 / 128.0 + 1e-6);
        }
    }

    #[test]
    fn signed_msb_split_identity() {
        // value = magnitude_bits - msb * 2^(n-1)  (paper Fig. 3)
        let f = SignedFixedFormat::new(6);
        for code in 0..64u32 {
            let xb = f.magnitude_bits(code) as i64;
            let msb = f.msb(code) as i64;
            let v = xb - msb * (1 << 5);
            let expect = if code >> 5 == 1 {
                code as i64 - 64
            } else {
                code as i64
            };
            assert_eq!(v, expect);
        }
    }

    #[test]
    fn signed_negative_has_msb() {
        let f = SignedFixedFormat::new(4);
        assert_eq!(f.msb(f.quantize(-0.5)), 1);
        assert_eq!(f.msb(f.quantize(0.5)), 0);
    }
}
