//! LUT-based stochastic rounding (paper §Stochastic rounding).
//!
//! The paper augments the rounding function with a counter input: a
//! sequence r(0..R) of pseudo-random thresholds is *baked into the
//! table*, so at inference time rounding is a pure table lookup —
//!
//!   f(x, i) = floor(x)      if r(i) <= 1 + (floor(x) - x)/eps
//!             floor(x)+eps  otherwise
//!
//! and the LUT size is R * 2^β(I) * β(O) bits.

use crate::util::Rng;

/// A stochastic-rounding LUT from `in_bits`-bit fixed codes to
/// `out_bits`-bit codes (out_bits < in_bits; eps = 2^(in_bits-out_bits)
/// input steps). Indexed by (code, counter).
#[derive(Debug, Clone)]
pub struct StochasticRounder {
    pub in_bits: u32,
    pub out_bits: u32,
    /// Number of dither phases R.
    pub phases: u32,
    /// table[(i * 2^in_bits) + code] = rounded out-code.
    table: Vec<u32>,
    counter: u32,
}

impl StochasticRounder {
    /// Build the table. `r(i)` is drawn from the deterministic PRNG so
    /// the whole pipeline stays reproducible (the paper also allows a
    /// 1-d dither/halftoning sequence — see [`Self::with_thresholds`]).
    pub fn new(in_bits: u32, out_bits: u32, phases: u32, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let thresholds: Vec<f64> = (0..phases).map(|_| rng.f64()).collect();
        Self::with_thresholds(in_bits, out_bits, &thresholds)
    }

    /// Build with the classic 4x4 Bayer ordered-dither thresholds — the
    /// paper's footnote 4: "r(i) can also be chosen using a 1-d
    /// dithering or halftoning algorithm". 16 phases, uniformly spread.
    pub fn bayer(in_bits: u32, out_bits: u32) -> Self {
        const BAYER4: [u8; 16] = [0, 8, 2, 10, 12, 4, 14, 6, 3, 11, 1, 9, 15, 7, 13, 5];
        let thresholds: Vec<f64> =
            BAYER4.iter().map(|&v| (v as f64 + 0.5) / 16.0).collect();
        Self::with_thresholds(in_bits, out_bits, &thresholds)
    }

    /// Build with explicit thresholds r(i) in [0,1) — e.g. a Bayer /
    /// void-and-cluster dither sequence.
    pub fn with_thresholds(in_bits: u32, out_bits: u32, thresholds: &[f64]) -> Self {
        assert!(out_bits < in_bits, "rounding must drop bits");
        assert!(in_bits <= 16);
        let phases = thresholds.len() as u32;
        assert!(phases >= 1);
        let drop = in_bits - out_bits;
        let eps = 1u32 << drop; // out-step measured in in-steps
        let n_in = 1u32 << in_bits;
        let out_max = (1u32 << out_bits) - 1;
        let mut table = Vec::with_capacity((phases * n_in) as usize);
        for &r in thresholds {
            for code in 0..n_in {
                let floor = code >> drop; // floor(x) in out-steps
                let frac = (code & (eps - 1)) as f64 / eps as f64; // x - floor(x)
                // r <= 1 - frac  => round down
                let rounded = if r <= 1.0 - frac { floor } else { floor + 1 };
                table.push(rounded.min(out_max));
            }
        }
        StochasticRounder { in_bits, out_bits, phases, table, counter: 0 }
    }

    /// Round one code; increments the counter (mod R) exactly as the
    /// paper specifies ("the index i is incremented (modulo R) each time
    /// the LUT table is accessed").
    #[inline]
    pub fn round(&mut self, code: u32) -> u32 {
        debug_assert!(code < 1 << self.in_bits);
        let idx = (self.counter * (1 << self.in_bits) + code) as usize;
        self.counter = (self.counter + 1) % self.phases;
        self.table[idx]
    }

    /// Deterministic round at an explicit phase (no counter mutation).
    #[inline]
    pub fn round_at(&self, code: u32, phase: u32) -> u32 {
        self.table[((phase % self.phases) * (1 << self.in_bits) + code) as usize]
    }

    /// LUT size in bits: R * 2^β(I) * β(O)  (paper formula).
    pub fn size_bits(&self) -> u64 {
        self.phases as u64 * (1u64 << self.in_bits) * self.out_bits as u64
    }

    pub fn reset(&mut self) {
        self.counter = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_values_never_move() {
        // codes that are multiples of eps are exact in the output grid
        let mut r = StochasticRounder::new(8, 4, 16, 1);
        for phase in 0..16 {
            for out_code in 0..16u32 {
                let code = out_code << 4;
                assert_eq!(r.round_at(code, phase), out_code);
            }
        }
        r.reset();
    }

    #[test]
    fn rounds_to_adjacent_levels_only() {
        let r = StochasticRounder::new(8, 4, 32, 2);
        for phase in 0..32 {
            for code in 0..256u32 {
                let out = r.round_at(code, phase);
                let floor = code >> 4;
                assert!(out == floor || out == (floor + 1).min(15));
            }
        }
    }

    #[test]
    fn expectation_is_unbiased() {
        // average over many phases approximates the fractional part
        let r = StochasticRounder::new(8, 4, 4096, 3);
        let code = 0x13; // floor=1, frac=3/16
        let mean: f64 = (0..4096)
            .map(|p| r.round_at(code, p) as f64)
            .sum::<f64>()
            / 4096.0;
        let expect = 1.0 + 3.0 / 16.0;
        assert!((mean - expect).abs() < 0.03, "mean {mean} expect {expect}");
    }

    #[test]
    fn counter_cycles_modulo_r() {
        let mut r = StochasticRounder::new(4, 2, 3, 4);
        let a: Vec<u32> = (0..6).map(|_| r.round(0b0110)).collect();
        assert_eq!(a[0..3], a[3..6], "counter must cycle with period R");
    }

    #[test]
    fn size_formula_matches_paper() {
        let r = StochasticRounder::new(8, 4, 16, 5);
        // R * 2^β(I) * β(O) = 16 * 256 * 4
        assert_eq!(r.size_bits(), 16 * 256 * 4);
    }

    #[test]
    fn bayer_dither_is_exactly_unbiased_over_a_period() {
        // Bayer thresholds are uniformly spaced, so the mean over one
        // full period is exact (not just statistically close): a code
        // with fractional part f/16 rounds up in exactly f of 16 phases.
        let r = StochasticRounder::bayer(8, 4);
        assert_eq!(r.phases, 16);
        for code in 0..256u32 {
            let sum: u32 = (0..16).map(|p| r.round_at(code, p)).sum();
            let floor = code >> 4;
            let frac = code & 15;
            let expect = if floor == 15 {
                16 * 15 // saturated at the top level
            } else {
                16 * floor + frac
            };
            assert_eq!(sum, expect, "code {code}");
        }
    }

    #[test]
    fn saturates_at_top() {
        let r = StochasticRounder::new(8, 4, 8, 6);
        for phase in 0..8 {
            assert_eq!(r.round_at(255, phase), 15);
        }
    }
}
