//! IEEE 754 binary16 codec, written from scratch (no `half` crate): the
//! paper's intermediate-layer format ("IEEE 754 binary16 16-bit floating
//! point format for the output of the first layer and the second layer").
//!
//! Layout: 1 sign bit | 5 exponent bits (bias 15) | 10 fraction bits.
//! The paper indexes LUTs with the *entire* exponent plus one mantissa
//! bitplane at a time (Fig. 1); [`F16::significand11`] exposes the 11-bit
//! significand (implicit bit included — "the precision in the mantissa of
//! the IEEE 754 binary16 format is 11 bits").

/// A binary16 value stored as its bit pattern.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct F16(pub u16);

pub const EXP_BITS: u32 = 5;
pub const FRAC_BITS: u32 = 10;
/// Mantissa precision including the implicit leading 1.
pub const SIG_BITS: u32 = 11;
pub const EXP_BIAS: i32 = 15;

impl F16 {
    /// Encode an f32 with round-to-nearest-even (the IEEE default).
    pub fn from_f32(x: f32) -> F16 {
        let bits = x.to_bits();
        let sign = ((bits >> 31) & 1) as u16;
        let exp = ((bits >> 23) & 0xFF) as i32;
        let frac = bits & 0x7F_FFFF;

        if exp == 0xFF {
            // Inf / NaN
            let f16_frac = if frac != 0 { 0x200 } else { 0 };
            return F16((sign << 15) | (0x1F << 10) | f16_frac);
        }

        // unbiased exponent
        let e = exp - 127;
        if e > 15 {
            // overflow -> inf
            return F16((sign << 15) | (0x1F << 10));
        }
        if e >= -14 {
            // normal range: round 23-bit frac to 10 bits, RNE
            let mut f = frac >> 13;
            let rem = frac & 0x1FFF;
            let halfway = 0x1000;
            if rem > halfway || (rem == halfway && (f & 1) == 1) {
                f += 1;
            }
            let mut e16 = (e + EXP_BIAS) as u32;
            if f == 0x400 {
                // rounding carried into the exponent
                f = 0;
                e16 += 1;
                if e16 >= 0x1F {
                    return F16((sign << 15) | (0x1F << 10));
                }
            }
            return F16((sign << 15) | ((e16 as u16) << 10) | f as u16);
        }
        if e >= -25 {
            // subnormal in f16: value = f * 2^-24 with f = sig * 2^(e+1)
            // where sig is the 24-bit significand (implicit bit added);
            // e in [-25, -15] so the shift is 14..=24 (e = -25 rounds to
            // either 0 or the smallest subnormal under RNE).
            let sig = 0x80_0000 | frac; // add implicit bit
            let total_shift = (-1 - e) as u32;
            let mut f = sig >> total_shift;
            let rem_mask = (1u32 << total_shift) - 1;
            let rem = sig & rem_mask;
            let halfway = 1u32 << (total_shift - 1);
            if rem > halfway || (rem == halfway && (f & 1) == 1) {
                f += 1;
            }
            return F16((sign << 15) | f as u16);
        }
        // underflow -> signed zero
        F16(sign << 15)
    }

    /// Decode to f32 (exact — every binary16 value is representable).
    pub fn to_f32(self) -> f32 {
        let sign = ((self.0 >> 15) & 1) as u32;
        let exp = ((self.0 >> 10) & 0x1F) as u32;
        let frac = (self.0 & 0x3FF) as u32;
        let f32bits = if exp == 0 {
            if frac == 0 {
                sign << 31
            } else {
                // subnormal: renormalize
                let mut e = -14i32;
                let mut f = frac;
                while f & 0x400 == 0 {
                    f <<= 1;
                    e -= 1;
                }
                f &= 0x3FF;
                (sign << 31) | (((e + 127) as u32) << 23) | (f << 13)
            }
        } else if exp == 0x1F {
            (sign << 31) | (0xFF << 23) | (frac << 13)
        } else {
            (sign << 31) | ((exp + 127 - 15) << 23) | (frac << 13)
        };
        f32::from_bits(f32bits)
    }

    /// Quantize-dequantize through binary16 (fake-quant for training and
    /// for the engine's intermediate activations).
    pub fn fake_quant(x: f32) -> f32 {
        F16::from_f32(x).to_f32()
    }

    pub fn sign(self) -> u32 {
        ((self.0 >> 15) & 1) as u32
    }

    /// Raw 5-bit exponent field (0 = zero/subnormal, 31 = inf/nan).
    pub fn exponent(self) -> u32 {
        ((self.0 >> 10) & 0x1F) as u32
    }

    /// Raw 10-bit fraction field.
    pub fn fraction(self) -> u32 {
        (self.0 & 0x3FF) as u32
    }

    /// 11-bit significand with the implicit bit made explicit (0 for
    /// zero/subnormals' leading bit). This is what the paper splits into
    /// 11 bitplanes.
    pub fn significand11(self) -> u32 {
        if self.exponent() == 0 {
            self.fraction() // subnormal: implicit bit is 0
        } else {
            0x400 | self.fraction()
        }
    }

    /// Bit `j` (0 = LSB) of the 11-bit significand.
    pub fn sig_bitplane(self, j: u32) -> u32 {
        debug_assert!(j < SIG_BITS);
        (self.significand11() >> j) & 1
    }

    /// The value this f16 represents, reconstructed from exponent and
    /// significand: (-1)^s * sig11 * 2^(e - 15 - 10)  (normals),
    /// sig11 * 2^(-14 - 10) (subnormals). Used by tests to prove the
    /// bitplane-LUT decomposition is exact.
    pub fn decompose_value(self) -> f64 {
        let s = if self.sign() == 1 { -1.0 } else { 1.0 };
        let e = self.exponent();
        let scale_exp = if e == 0 {
            -14 - FRAC_BITS as i32
        } else {
            e as i32 - EXP_BIAS - FRAC_BITS as i32
        };
        s * self.significand11() as f64 * (scale_exp as f64).exp2()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_encodings() {
        assert_eq!(F16::from_f32(0.0).0, 0x0000);
        assert_eq!(F16::from_f32(1.0).0, 0x3C00);
        assert_eq!(F16::from_f32(-2.0).0, 0xC000);
        assert_eq!(F16::from_f32(0.5).0, 0x3800);
        assert_eq!(F16::from_f32(65504.0).0, 0x7BFF); // f16 max
        assert_eq!(F16::from_f32(f32::INFINITY).0, 0x7C00);
    }

    #[test]
    fn known_decodings() {
        assert_eq!(F16(0x3C00).to_f32(), 1.0);
        assert_eq!(F16(0xC000).to_f32(), -2.0);
        assert_eq!(F16(0x7BFF).to_f32(), 65504.0);
        assert_eq!(F16(0x0001).to_f32(), 5.9604645e-8); // smallest subnormal
        assert!(F16(0x7C01).to_f32().is_nan());
    }

    #[test]
    fn roundtrip_exact_for_f16_values() {
        // every finite f16 bit pattern decodes and re-encodes to itself
        for bits in 0..=0xFFFFu16 {
            let exp = (bits >> 10) & 0x1F;
            if exp == 0x1F {
                continue; // inf/nan
            }
            let x = F16(bits).to_f32();
            assert_eq!(F16::from_f32(x).0, bits, "bits {bits:#06x}");
        }
    }

    #[test]
    fn rounding_is_nearest_even() {
        // 1.0 + 2^-11 is exactly halfway between 1.0 and 1.0+2^-10:
        // rounds to even (1.0)
        let x = 1.0 + (2.0f32).powi(-11);
        assert_eq!(F16::from_f32(x).0, 0x3C00);
        // slightly above halfway rounds up
        let y = 1.0 + (2.0f32).powi(-11) + (2.0f32).powi(-20);
        assert_eq!(F16::from_f32(y).0, 0x3C01);
    }

    #[test]
    fn overflow_to_inf_and_underflow_to_zero() {
        assert_eq!(F16::from_f32(1e6).0, 0x7C00);
        assert_eq!(F16::from_f32(-1e6).0, 0xFC00);
        assert_eq!(F16::from_f32(1e-10).0, 0x0000);
    }

    #[test]
    fn subnormal_encoding() {
        // 2^-15 = 0.5 * 2^-14 -> subnormal with frac 0x200
        assert_eq!(F16::from_f32((2.0f32).powi(-15)).0, 0x0200);
        assert_eq!(F16::from_f32((2.0f32).powi(-24)).0, 0x0001);
    }

    #[test]
    fn quantization_error_bounded_relative() {
        // normals: relative error <= 2^-11
        let mut x = 0.001f32;
        while x < 60000.0 {
            let q = F16::fake_quant(x);
            let rel = ((q - x) / x).abs();
            assert!(rel <= (2.0f32).powi(-11), "x={x} q={q} rel={rel}");
            x *= 1.37;
        }
    }

    #[test]
    fn significand_has_implicit_bit() {
        let one = F16::from_f32(1.0);
        assert_eq!(one.significand11(), 0x400);
        assert_eq!(one.exponent(), 15);
        let sub = F16(0x0001);
        assert_eq!(sub.significand11(), 1); // no implicit bit
    }

    #[test]
    fn bitplane_decomposition_is_exact() {
        // sum over bitplanes of (bit << j) rebuilds the significand, and
        // decompose_value matches to_f32 — the identity the LUT engine
        // relies on.
        for bits in [0x3C00u16, 0x3555, 0x7BFF, 0x0001, 0x0200, 0x4248] {
            let h = F16(bits);
            let rebuilt: u32 = (0..SIG_BITS).map(|j| h.sig_bitplane(j) << j).sum();
            assert_eq!(rebuilt, h.significand11());
            let v = h.decompose_value();
            assert!(
                (v - h.to_f32() as f64).abs() <= 1e-12 * v.abs().max(1e-30),
                "bits {bits:#06x}: {v} vs {}",
                h.to_f32()
            );
        }
    }
}
