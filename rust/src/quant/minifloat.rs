//! 8-bit minifloat (the paper cites minifloats as an example of a small
//! input set `I` with β(I)=8). Configurable exponent/mantissa split with
//! a sign bit; default 1-4-3 (sign, 4 exp, 3 frac), IEEE-like with
//! subnormals, round-to-nearest-even, no infinities (saturating).

/// Minifloat format descriptor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MiniFloatFormat {
    pub exp_bits: u32,
    pub frac_bits: u32,
}

impl MiniFloatFormat {
    pub fn new(exp_bits: u32, frac_bits: u32) -> Self {
        assert!(exp_bits >= 2 && frac_bits >= 1 && 1 + exp_bits + frac_bits <= 8);
        MiniFloatFormat { exp_bits, frac_bits }
    }

    /// The classic 8-bit minifloat: 1 sign, 4 exponent, 3 fraction.
    pub fn e4m3() -> Self {
        MiniFloatFormat::new(4, 3)
    }

    pub fn bits(&self) -> u32 {
        1 + self.exp_bits + self.frac_bits
    }

    pub fn bias(&self) -> i32 {
        (1 << (self.exp_bits - 1)) - 1
    }

    fn max_exp(&self) -> i32 {
        ((1 << self.exp_bits) - 1) - self.bias()
    }

    /// Largest finite value.
    pub fn max_value(&self) -> f32 {
        let frac_max = 1.0 + ((1u32 << self.frac_bits) - 1) as f32
            / (1u32 << self.frac_bits) as f32;
        frac_max * (self.max_exp() as f32).exp2()
    }

    /// Encode f32 -> code in the low `bits()` bits. Saturating at
    /// `max_value`, flushes tiny values through the subnormal range.
    pub fn encode(&self, x: f32) -> u8 {
        let sign = if x.is_sign_negative() { 1u8 } else { 0 };
        let ax = x.abs();
        let sbit = sign << (self.exp_bits + self.frac_bits);
        if ax == 0.0 || ax.is_nan() {
            return sbit;
        }
        if ax >= self.max_value() {
            // saturate to max finite
            let code = (((1u32 << self.exp_bits) - 1) << self.frac_bits
                | ((1 << self.frac_bits) - 1)) as u8;
            return sbit | code;
        }
        let e = ax.log2().floor() as i32;
        let min_norm_exp = 1 - self.bias();
        if e >= min_norm_exp {
            // normal
            let mant = ax / (e as f32).exp2(); // in [1, 2)
            let scaled = (mant - 1.0) * (1u32 << self.frac_bits) as f32;
            let mut f = scaled.round_ties_even() as u32;
            let mut ecode = (e + self.bias()) as u32;
            if f == 1 << self.frac_bits {
                f = 0;
                ecode += 1;
                if ecode >= (1 << self.exp_bits) {
                    // saturate
                    return sbit
                        | ((((1u32 << self.exp_bits) - 1) << self.frac_bits)
                            | ((1 << self.frac_bits) - 1)) as u8;
                }
            }
            sbit | ((ecode << self.frac_bits) | f) as u8
        } else {
            // subnormal: value = f * 2^(min_norm_exp - frac_bits)
            let step = ((min_norm_exp - self.frac_bits as i32) as f32).exp2();
            let f = (ax / step).round_ties_even() as u32;
            if f >= 1 << self.frac_bits {
                // rounded up into the normal range
                return sbit | (1u32 << self.frac_bits) as u8;
            }
            sbit | f as u8
        }
    }

    /// Decode a code back to f32.
    pub fn decode(&self, code: u8) -> f32 {
        let code = code as u32;
        let sign = (code >> (self.exp_bits + self.frac_bits)) & 1;
        let ecode = (code >> self.frac_bits) & ((1 << self.exp_bits) - 1);
        let f = code & ((1 << self.frac_bits) - 1);
        let mag = if ecode == 0 {
            f as f32 * ((1 - self.bias() - self.frac_bits as i32) as f32).exp2()
        } else {
            (1.0 + f as f32 / (1u32 << self.frac_bits) as f32)
                * ((ecode as i32 - self.bias()) as f32).exp2()
        };
        if sign == 1 {
            -mag
        } else {
            mag
        }
    }

    /// Quantize-dequantize through the minifloat.
    pub fn fake_quant(&self, x: f32) -> f32 {
        self.decode(self.encode(x))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e4m3_basics() {
        let f = MiniFloatFormat::e4m3();
        assert_eq!(f.bits(), 8);
        assert_eq!(f.bias(), 7);
        assert_eq!(f.fake_quant(1.0), 1.0);
        assert_eq!(f.fake_quant(0.0), 0.0);
        assert_eq!(f.fake_quant(-1.5), -1.5);
    }

    #[test]
    fn roundtrip_all_codes() {
        let f = MiniFloatFormat::e4m3();
        for code in 0u8..=255 {
            let x = f.decode(code);
            let back = f.encode(x);
            // -0 and +0 collapse; everything else must round-trip
            if x == 0.0 {
                assert_eq!(back & 0x7F, 0);
            } else {
                assert_eq!(back, code, "code {code:#04x} -> {x} -> {back:#04x}");
            }
        }
    }

    #[test]
    fn saturates_at_max() {
        let f = MiniFloatFormat::e4m3();
        let m = f.max_value();
        assert_eq!(f.fake_quant(m * 100.0), m);
        assert_eq!(f.fake_quant(-m * 100.0), -m);
    }

    #[test]
    fn subnormals_representable() {
        let f = MiniFloatFormat::e4m3();
        // smallest subnormal = 2^(1-7-3) = 2^-9
        let tiny = (2.0f32).powi(-9);
        assert_eq!(f.fake_quant(tiny), tiny);
    }

    #[test]
    fn relative_error_bounded_for_normals() {
        let f = MiniFloatFormat::e4m3();
        let mut x = 0.02f32;
        while x < f.max_value() {
            let rel = ((f.fake_quant(x) - x) / x).abs();
            assert!(rel <= 1.0 / 16.0, "x={x} rel={rel}");
            x *= 1.7;
        }
    }

    #[test]
    fn other_splits_work() {
        let f = MiniFloatFormat::new(5, 2);
        assert_eq!(f.fake_quant(2.0), 2.0);
        let g = MiniFloatFormat::new(2, 3);
        assert_eq!(g.fake_quant(1.25), 1.25);
    }
}
