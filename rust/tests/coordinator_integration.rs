//! Coordinator integration: the serving stack over the real LUT engine,
//! including load, backpressure, failure injection, multi-model
//! registry serving with mid-load hot-swaps, and the end-to-end
//! per-model multiplier-less invariant.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use tablenet::config::ServeConfig;
use tablenet::coordinator::registry::{ModelRegistry, RegistryError};
use tablenet::coordinator::{Backend, Coordinator, InferOutput, SubmitError};
use tablenet::data::synth::Kind;
use tablenet::data::Split;
use tablenet::engine::counters::Counters;
use tablenet::engine::plan::{AffineMode, EnginePlan};
use tablenet::engine::{Compiler, LutModel};
use tablenet::nn::Model;
use tablenet::train::{train_dense, TrainConfig};

fn toy_split(n: usize, seed: u64) -> Split {
    let (px, lb) = tablenet::data::synth::generate(Kind::Digits, n, seed);
    Split {
        images: px.iter().map(|&v| v as f32 / 255.0).collect(),
        labels: lb.iter().map(|&v| v as usize).collect(),
    }
}

fn toy_model(train: &Split) -> Model {
    train_dense(
        train,
        &[784, 10],
        &TrainConfig { steps: 400, lr: 0.25, ..Default::default() },
    )
}

fn trained_engine() -> (LutModel, Split) {
    let train = toy_split(800, 21);
    let test = toy_split(200, 22);
    let model = toy_model(&train);
    (
        Compiler::new(&model).plan(&EnginePlan::linear_default()).build().unwrap(),
        test,
    )
}

#[test]
fn serve_run_preserves_accuracy_and_multiplier_less_invariant() {
    let (engine, test) = trained_engine();
    // engine accuracy measured directly
    let (direct_acc, _) = engine.accuracy(&test.images, 784, &test.labels);

    let coord = Coordinator::start(
        Arc::new(engine),
        &ServeConfig {
            max_batch: 16,
            max_wait_us: 300,
            workers: 2,
            queue_cap: 512,
            ..ServeConfig::default()
        },
    );
    let test = Arc::new(test);
    let mut joins = Vec::new();
    for t in 0..4usize {
        let client = coord.client();
        let test = test.clone();
        joins.push(std::thread::spawn(move || {
            let mut correct = 0usize;
            for i in 0..50 {
                let idx = (t * 50 + i) % test.len();
                let r = client.infer_blocking(test.image(idx).to_vec()).unwrap();
                if r.class == test.labels[idx] {
                    correct += 1;
                }
            }
            correct
        }));
    }
    let correct: usize = joins.into_iter().map(|j| j.join().unwrap()).sum();
    let snap = coord.shutdown();
    assert_eq!(snap.completed, 200);
    snap.ops.assert_multiplier_less();
    let served_acc = correct as f64 / 200.0;
    assert!(
        (served_acc - direct_acc).abs() < 0.1,
        "served accuracy {served_acc} vs direct {direct_acc}"
    );
    // per-request op counters aggregated: 200 requests x 168 evals
    assert_eq!(snap.ops.lut_evals, 200 * 168);
}

#[test]
fn saturation_rejects_but_never_loses_accepted_requests() {
    struct Slow(AtomicUsize);
    impl Backend for Slow {
        fn infer_batch(&self, images: &[Vec<f32>]) -> Vec<InferOutput> {
            std::thread::sleep(std::time::Duration::from_millis(5));
            self.0.fetch_add(images.len(), Ordering::SeqCst);
            images
                .iter()
                .map(|_| InferOutput {
                    class: 0,
                    logits: vec![0.0],
                    counters: Counters::default(),
                })
                .collect()
        }
        fn name(&self) -> &'static str {
            "slow"
        }
    }
    let backend = Arc::new(Slow(AtomicUsize::new(0)));
    let coord = Coordinator::start(
        backend.clone(),
        &ServeConfig {
            max_batch: 4,
            max_wait_us: 100,
            workers: 1,
            queue_cap: 8,
            ..ServeConfig::default()
        },
    );
    let mut joins = Vec::new();
    for _ in 0..64 {
        let client = coord.client();
        joins.push(std::thread::spawn(move || client.infer(vec![0.0]).is_ok()));
    }
    let accepted = joins.into_iter().filter(|_| true).map(|j| j.join().unwrap()).filter(|&ok| ok).count();
    let snap = coord.shutdown();
    // every accepted request was executed exactly once
    assert_eq!(snap.completed as usize, accepted);
    assert_eq!(backend.0.load(Ordering::SeqCst), accepted);
    assert_eq!(snap.completed + snap.rejected, 64);
}

#[test]
fn requests_after_shutdown_fail_cleanly() {
    let (engine, test) = trained_engine();
    let coord = Coordinator::start(Arc::new(engine), &ServeConfig::default());
    let client = coord.client();
    let img = test.image(0).to_vec();
    assert!(client.infer_blocking(img.clone()).is_ok());
    coord.shutdown();
    // the pipeline is gone; a subsequent submit must error, not hang
    match client.infer_blocking(img) {
        Err(SubmitError::ShutDown) => {}
        other => panic!("expected ShutDown, got {other:?}"),
    }
}

/// The ISSUE acceptance scenario: a running registry serves two named
/// `.ltm` models concurrently and survives a mid-load hot-swap with
/// zero lost requests, zero mixed-version batches (version-exact
/// responses) and exact per-model op counters — zero multiplies in
/// every model's snapshot, artifacts only, no weights on the serve
/// path.
#[test]
fn registry_serves_two_ltm_models_and_survives_midload_swap() {
    let dir = std::env::temp_dir().join("tablenet_registry_swap");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    let train = toy_split(600, 31);
    let test = Arc::new(toy_split(120, 32));
    let model = toy_model(&train);
    let plan_bits = |bits: u32| EnginePlan {
        affine: vec![AffineMode::BitplaneFixed { bits, m: 14, range_exp: 0 }],
        fallback: AffineMode::Float { planes: 11, m: 1 },
        r_o: 16,
    };
    // two named artifacts on disk; the registry loads them back — the
    // serve path never touches weights
    let save = |bits: u32, name: &str| -> LutModel {
        let lut = Compiler::new(&model).plan(&plan_bits(bits)).build().unwrap();
        let path = dir.join(name);
        lut.save(&path).unwrap();
        LutModel::load(&path).unwrap()
    };
    let reg = ModelRegistry::new();
    reg.register(
        "alpha",
        Arc::new(save(3, "alpha.ltm")),
        &ServeConfig {
            max_batch: 16,
            max_wait_us: 200,
            workers: 2,
            queue_cap: 512,
            ..ServeConfig::default()
        },
    )
    .unwrap();
    reg.register(
        "beta",
        Arc::new(save(2, "beta.ltm")),
        &ServeConfig {
            max_batch: 4,
            max_wait_us: 50,
            workers: 1,
            queue_cap: 512,
            ..ServeConfig::default()
        },
    )
    .unwrap();

    // per-inference op profile of each version, for exact attribution
    let probe = |lut: &LutModel| lut.infer(&test.images[..784]).counters;
    let alpha_v1_ops = probe(&save(3, "alpha_probe.ltm"));
    let alpha_v2_ops = probe(&save(4, "alpha_v2_probe.ltm"));

    let mut joins = Vec::new();
    for t in 0..4usize {
        let client = reg.client();
        let test = test.clone();
        joins.push(std::thread::spawn(move || {
            let mut alpha = Vec::new();
            let mut beta = 0usize;
            for i in 0..60 {
                let idx = (t * 60 + i) % test.len();
                let row = test.images[idx * 784..(idx + 1) * 784].to_vec();
                let name = if i % 2 == 0 { "alpha" } else { "beta" };
                let r = client.infer(name, row).unwrap();
                if name == "alpha" {
                    alpha.push(r.version);
                } else {
                    assert_eq!(r.version, 1, "beta was never swapped");
                    beta += 1;
                }
            }
            (alpha, beta)
        }));
    }

    // hot-swap alpha to v2 (sharper input bits) while the load runs
    let v2 = Arc::new(save(4, "alpha_v2.ltm"));
    std::thread::sleep(std::time::Duration::from_millis(3));
    assert_eq!(reg.swap("alpha", v2).unwrap(), 2);

    let mut alpha_versions = Vec::new();
    let mut beta_served = 0usize;
    for j in joins {
        let (a, b) = j.join().unwrap();
        alpha_versions.extend(a);
        beta_served += b;
    }
    // zero lost requests on both tenants
    assert_eq!(alpha_versions.len(), 120);
    assert_eq!(beta_served, 120);
    assert!(alpha_versions.iter().all(|&v| v == 1 || v == 2));

    let fleet = reg.shutdown();
    assert_eq!(fleet.models["alpha"].stats.completed, 120);
    assert_eq!(fleet.models["beta"].stats.completed, 120);
    assert_eq!(fleet.models["alpha"].version, 2);
    assert_eq!(fleet.models["beta"].version, 1);
    assert_eq!(fleet.models["alpha"].stats.swaps, 1);
    // exact per-model counters: alpha's total is the exact mix of v1-
    // and v2-served requests (every row identical per version for a
    // linear plan), beta's is 120x its per-inference profile
    let v1_count = alpha_versions.iter().filter(|&&v| v == 1).count() as u64;
    let v2_count = 120 - v1_count;
    assert_eq!(
        fleet.models["alpha"].stats.ops.lut_evals,
        v1_count * alpha_v1_ops.lut_evals + v2_count * alpha_v2_ops.lut_evals
    );
    let beta_ops = probe(&save(2, "beta_probe.ltm"));
    assert_eq!(fleet.models["beta"].stats.ops.lut_evals, 120 * beta_ops.lut_evals);
    // zero multiplies per model snapshot, not just in aggregate
    fleet.assert_multiplier_less();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn retire_drains_and_isolates_remaining_models() {
    let (engine, test) = trained_engine();
    let model2 = toy_model(&toy_split(600, 41));
    let engine2 =
        Compiler::new(&model2).plan(&EnginePlan::linear_default()).build().unwrap();
    let reg = ModelRegistry::new();
    let cfg = ServeConfig {
        max_batch: 8,
        max_wait_us: 100,
        workers: 1,
        queue_cap: 256,
        ..ServeConfig::default()
    };
    reg.register("keep", Arc::new(engine), &cfg).unwrap();
    reg.register("drop", Arc::new(engine2), &cfg).unwrap();
    let client = reg.client();
    let row = || test.images[..784].to_vec();
    for _ in 0..10 {
        client.infer("keep", row()).unwrap();
        client.infer("drop", row()).unwrap();
    }
    let snap = reg.retire("drop").unwrap();
    assert_eq!(snap.completed, 10);
    snap.ops.assert_multiplier_less();
    // retired name routes to a clean error; the survivor still serves
    assert!(client.infer("drop", row()).is_err());
    assert!(matches!(reg.retire("drop"), Err(RegistryError::UnknownModel(_))));
    for _ in 0..5 {
        client.infer("keep", row()).unwrap();
    }
    let fleet = reg.shutdown();
    assert_eq!(fleet.models.len(), 1);
    assert_eq!(fleet.models["keep"].stats.completed, 15);
    fleet.assert_multiplier_less();
}

#[test]
fn batching_amortizes_throughput() {
    // with a per-batch fixed cost backend, larger max_batch must yield
    // fewer batches for the same request count
    struct Counting;
    impl Backend for Counting {
        fn infer_batch(&self, images: &[Vec<f32>]) -> Vec<InferOutput> {
            std::thread::sleep(std::time::Duration::from_micros(300));
            images
                .iter()
                .map(|_| InferOutput {
                    class: 0,
                    logits: vec![],
                    counters: Counters::default(),
                })
                .collect()
        }
        fn name(&self) -> &'static str {
            "counting"
        }
    }
    let mut batch_counts = Vec::new();
    for max_batch in [1usize, 16] {
        let coord = Coordinator::start(
            Arc::new(Counting),
            &ServeConfig {
                max_batch,
                max_wait_us: 2000,
                workers: 1,
                queue_cap: 256,
                ..ServeConfig::default()
            },
        );
        let mut joins = Vec::new();
        for _ in 0..8 {
            let client = coord.client();
            joins.push(std::thread::spawn(move || {
                for _ in 0..16 {
                    client.infer_blocking(vec![0.0]).unwrap();
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let snap = coord.shutdown();
        assert_eq!(snap.completed, 128);
        batch_counts.push(snap.batches);
    }
    assert!(
        batch_counts[1] < batch_counts[0],
        "batching had no effect: {batch_counts:?}"
    );
}
