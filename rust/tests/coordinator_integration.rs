//! Coordinator integration: the serving stack over the real LUT engine,
//! including load, backpressure, failure injection and the end-to-end
//! multiplier-less invariant.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use tablenet::config::ServeConfig;
use tablenet::coordinator::{Backend, Coordinator, InferOutput, SubmitError};
use tablenet::data::synth::Kind;
use tablenet::data::Split;
use tablenet::engine::counters::Counters;
use tablenet::engine::plan::EnginePlan;
use tablenet::engine::{Compiler, LutModel};
use tablenet::train::{train_dense, TrainConfig};

fn toy_split(n: usize, seed: u64) -> Split {
    let (px, lb) = tablenet::data::synth::generate(Kind::Digits, n, seed);
    Split {
        images: px.iter().map(|&v| v as f32 / 255.0).collect(),
        labels: lb.iter().map(|&v| v as usize).collect(),
    }
}

fn trained_engine() -> (LutModel, Split) {
    let train = toy_split(800, 21);
    let test = toy_split(200, 22);
    let model = train_dense(
        &train,
        &[784, 10],
        &TrainConfig { steps: 400, lr: 0.25, ..Default::default() },
    );
    (
        Compiler::new(&model).plan(&EnginePlan::linear_default()).build().unwrap(),
        test,
    )
}

#[test]
fn serve_run_preserves_accuracy_and_multiplier_less_invariant() {
    let (engine, test) = trained_engine();
    // engine accuracy measured directly
    let (direct_acc, _) = engine.accuracy(&test.images, 784, &test.labels);

    let coord = Coordinator::start(
        Arc::new(engine),
        &ServeConfig { max_batch: 16, max_wait_us: 300, workers: 2, queue_cap: 512 },
    );
    let test = Arc::new(test);
    let mut joins = Vec::new();
    for t in 0..4usize {
        let client = coord.client();
        let test = test.clone();
        joins.push(std::thread::spawn(move || {
            let mut correct = 0usize;
            for i in 0..50 {
                let idx = (t * 50 + i) % test.len();
                let r = client.infer_blocking(test.image(idx).to_vec()).unwrap();
                if r.class == test.labels[idx] {
                    correct += 1;
                }
            }
            correct
        }));
    }
    let correct: usize = joins.into_iter().map(|j| j.join().unwrap()).sum();
    let snap = coord.shutdown();
    assert_eq!(snap.completed, 200);
    snap.ops.assert_multiplier_less();
    let served_acc = correct as f64 / 200.0;
    assert!(
        (served_acc - direct_acc).abs() < 0.1,
        "served accuracy {served_acc} vs direct {direct_acc}"
    );
    // per-request op counters aggregated: 200 requests x 168 evals
    assert_eq!(snap.ops.lut_evals, 200 * 168);
}

#[test]
fn saturation_rejects_but_never_loses_accepted_requests() {
    struct Slow(AtomicUsize);
    impl Backend for Slow {
        fn infer_batch(&self, images: &[Vec<f32>]) -> Vec<InferOutput> {
            std::thread::sleep(std::time::Duration::from_millis(5));
            self.0.fetch_add(images.len(), Ordering::SeqCst);
            images
                .iter()
                .map(|_| InferOutput {
                    class: 0,
                    logits: vec![0.0],
                    counters: Counters::default(),
                })
                .collect()
        }
        fn name(&self) -> &'static str {
            "slow"
        }
    }
    let backend = Arc::new(Slow(AtomicUsize::new(0)));
    let coord = Coordinator::start(
        backend.clone(),
        &ServeConfig { max_batch: 4, max_wait_us: 100, workers: 1, queue_cap: 8 },
    );
    let mut joins = Vec::new();
    for _ in 0..64 {
        let client = coord.client();
        joins.push(std::thread::spawn(move || client.infer(vec![0.0]).is_ok()));
    }
    let accepted = joins.into_iter().filter(|_| true).map(|j| j.join().unwrap()).filter(|&ok| ok).count();
    let snap = coord.shutdown();
    // every accepted request was executed exactly once
    assert_eq!(snap.completed as usize, accepted);
    assert_eq!(backend.0.load(Ordering::SeqCst), accepted);
    assert_eq!(snap.completed + snap.rejected, 64);
}

#[test]
fn requests_after_shutdown_fail_cleanly() {
    let (engine, test) = trained_engine();
    let coord = Coordinator::start(Arc::new(engine), &ServeConfig::default());
    let client = coord.client();
    let img = test.image(0).to_vec();
    assert!(client.infer_blocking(img.clone()).is_ok());
    coord.shutdown();
    // the pipeline is gone; a subsequent submit must error, not hang
    match client.infer_blocking(img) {
        Err(SubmitError::ShutDown) => {}
        other => panic!("expected ShutDown, got {other:?}"),
    }
}

#[test]
fn batching_amortizes_throughput() {
    // with a per-batch fixed cost backend, larger max_batch must yield
    // fewer batches for the same request count
    struct Counting;
    impl Backend for Counting {
        fn infer_batch(&self, images: &[Vec<f32>]) -> Vec<InferOutput> {
            std::thread::sleep(std::time::Duration::from_micros(300));
            images
                .iter()
                .map(|_| InferOutput {
                    class: 0,
                    logits: vec![],
                    counters: Counters::default(),
                })
                .collect()
        }
        fn name(&self) -> &'static str {
            "counting"
        }
    }
    let mut batch_counts = Vec::new();
    for max_batch in [1usize, 16] {
        let coord = Coordinator::start(
            Arc::new(Counting),
            &ServeConfig { max_batch, max_wait_us: 2000, workers: 1, queue_cap: 256 },
        );
        let mut joins = Vec::new();
        for _ in 0..8 {
            let client = coord.client();
            joins.push(std::thread::spawn(move || {
                for _ in 0..16 {
                    client.infer_blocking(vec![0.0]).unwrap();
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let snap = coord.shutdown();
        assert_eq!(snap.completed, 128);
        batch_counts.push(snap.batches);
    }
    assert!(
        batch_counts[1] < batch_counts[0],
        "batching had no effect: {batch_counts:?}"
    );
}
