//! Cross-module integration: trained models -> LUT engine -> accuracy
//! tracks the reference across all three architectures; engine size and
//! eval counts agree with the planner; JAX artifacts load when present.

use std::path::Path;
use tablenet::data::synth::Kind;
use tablenet::data::{load_or_generate, Split};
use tablenet::engine::plan::{AffineMode, EnginePlan};
use tablenet::engine::Compiler;
use tablenet::nn::{weights, Arch, Model};
use tablenet::tensor::Tensor;
use tablenet::train::{train_dense, TrainConfig};
use tablenet::util::Rng;

fn toy_split(kind: Kind, n: usize, seed: u64) -> Split {
    let (px, lb) = tablenet::data::synth::generate(kind, n, seed);
    Split {
        images: px.iter().map(|&v| v as f32 / 255.0).collect(),
        labels: lb.iter().map(|&v| v as usize).collect(),
    }
}

#[test]
fn linear_lut_tracks_reference_accuracy() {
    let train = toy_split(Kind::Digits, 1200, 1);
    let test = toy_split(Kind::Digits, 400, 2);
    let model = train_dense(
        &train,
        &[784, 10],
        &TrainConfig { steps: 600, lr: 0.25, input_bits: Some(3), ..Default::default() },
    );
    let x = Tensor::new(&[test.len(), 784], test.images.clone());
    let ref_acc = model.accuracy(&x, &test.labels);

    let lut = Compiler::new(&model).plan(&EnginePlan::linear_default()).build().unwrap();
    let (lut_acc, ctr) = lut.accuracy(&test.images, 784, &test.labels);
    ctr.assert_multiplier_less();
    assert!(
        (lut_acc - ref_acc).abs() < 0.03,
        "LUT {lut_acc} vs ref {ref_acc} drifted"
    );
    // paper: 56 LUTs, 168 evals at 3 bits / m=14
    assert_eq!(ctr.lut_evals, 168);
}

#[test]
fn memory_parity_config_matches_reference_footprint() {
    // paper: "784 LUTs totaling about 30.6 KiB ... same memory footprint
    // as the reference model" (30.7 KiB)
    let train = toy_split(Kind::Digits, 400, 3);
    let model = train_dense(
        &train,
        &[784, 10],
        &TrainConfig { steps: 100, lr: 0.3, ..Default::default() },
    );
    let lut = Compiler::new(&model).plan(&EnginePlan::linear_parity()).build().unwrap();
    let lut_kib = lut.size_bits() as f64 / 8.0 / 1024.0;
    let ref_kib = model.weight_bytes() as f64 / 1024.0;
    assert!((lut_kib - 30.625).abs() < 0.1, "lut {lut_kib} KiB");
    assert!((ref_kib - 30.66).abs() < 0.1, "ref {ref_kib} KiB");
}

#[test]
fn small_mlp_float_pipeline_tracks_reference() {
    let train = toy_split(Kind::Digits, 1500, 5);
    let test = toy_split(Kind::Digits, 300, 6);
    let model = train_dense(
        &train,
        &[784, 64, 10],
        &TrainConfig { steps: 700, lr: 0.2, ..Default::default() },
    );
    let x = Tensor::new(&[test.len(), 784], test.images.clone());
    let ref_acc = model.accuracy(&x, &test.labels);
    let plan = EnginePlan {
        affine: vec![
            AffineMode::Float { planes: 11, m: 1 },
            AffineMode::Float { planes: 11, m: 1 },
        ],
        fallback: AffineMode::Float { planes: 11, m: 1 },
        r_o: 16,
    };
    let lut = Compiler::new(&model).plan(&plan).build().unwrap();
    let (acc, ctr) = lut.accuracy(&test.images, 784, &test.labels);
    ctr.assert_multiplier_less();
    assert!(
        (acc - ref_acc).abs() < 0.04,
        "MLP float pipeline {acc} vs ref {ref_acc}"
    );
}

#[test]
fn tiny_cnn_lut_matches_reference_forward() {
    // random-weight LeNet-shaped CNN on a small image: LUT forward must
    // classify like the quantized reference forward
    let mut rng = Rng::new(9);
    let model = Model::lenet(
        (Tensor::randn(&[5, 5, 1, 32], 0.08, &mut rng), Tensor::zeros(&[32])),
        (Tensor::randn(&[5, 5, 32, 64], 0.02, &mut rng), Tensor::zeros(&[64])),
        (Tensor::randn(&[1024, 3136], 0.01, &mut rng), Tensor::zeros(&[1024])),
        (Tensor::randn(&[10, 1024], 0.03, &mut rng), Tensor::zeros(&[10])),
    );
    let lut = Compiler::new(&model).plan(&EnginePlan::cnn_default()).build().unwrap();
    let test = toy_split(Kind::Digits, 3, 10);
    let mut agree = 0;
    for i in 0..3 {
        let img = test.image(i);
        let inf = lut.infer(img);
        inf.counters.assert_multiplier_less();
        let ref_out = model
            .with_quantization(8, true, 8)
            .forward(&Tensor::new(&[1, 28, 28, 1], img.to_vec()));
        if ref_out.argmax_rows()[0] == inf.class {
            agree += 1;
        }
    }
    assert!(agree >= 2, "CNN LUT agreed on only {agree}/3");
}

#[test]
fn jax_artifacts_load_and_classify_well_when_present() {
    // integration with the L2 compile path: uses `make artifacts` output
    let path = Path::new("artifacts/weights_linear.bin");
    if !path.exists() {
        eprintln!("skipping: {} not built", path.display());
        return;
    }
    let model = weights::load_model(Arch::Linear, path).unwrap();
    let ds = load_or_generate(Path::new("data/synth"), Kind::Digits, 6000, 1000, 7).unwrap();
    let lut = Compiler::new(&model).plan(&EnginePlan::linear_default()).build().unwrap();
    let (acc, _) = lut.accuracy(&ds.test.images, 784, &ds.test.labels);
    assert!(acc > 0.7, "JAX-trained linear LUT accuracy only {acc}");
}

#[test]
fn plan_ablation_fixed_inner_is_worse_than_float() {
    // the paper's finding: fixed-point inner layers lose accuracy vs f16
    let train = toy_split(Kind::Digits, 1500, 11);
    let test = toy_split(Kind::Digits, 300, 12);
    let model = train_dense(
        &train,
        &[784, 48, 10],
        &TrainConfig { steps: 700, lr: 0.2, ..Default::default() },
    );
    let float_plan = EnginePlan {
        affine: vec![
            AffineMode::Float { planes: 11, m: 1 },
            AffineMode::Float { planes: 11, m: 1 },
        ],
        fallback: AffineMode::Float { planes: 11, m: 1 },
        r_o: 16,
    };
    let fixed_plan = EnginePlan {
        affine: vec![
            AffineMode::WholeFixed { bits: 8, m: 1, range_exp: 0 },
            AffineMode::BitplaneFixed { bits: 4, m: 4, range_exp: 3 },
        ],
        fallback: AffineMode::Float { planes: 11, m: 1 },
        r_o: 16,
    };
    let (facc, _) = Compiler::new(&model).plan(&float_plan).build()
        .unwrap()
        .accuracy(&test.images, 784, &test.labels);
    let (xacc, _) = Compiler::new(&model).plan(&fixed_plan).build()
        .unwrap()
        .accuracy(&test.images, 784, &test.labels);
    assert!(
        facc + 0.02 >= xacc,
        "float pipeline ({facc}) should be >= low-bit fixed pipeline ({xacc})"
    );
}
