//! Chaos soak: the serving runtime under deterministic fault injection.
//!
//! A seeded [`FaultPlan`] injects worker panics and latency into live
//! pipelines while concurrent clients drive load and the control plane
//! performs quarantined swaps mid-soak. The invariants under test are
//! the runtime's whole fault-tolerance contract:
//!
//! * **zero lost requests** — every submitted request gets exactly one
//!   verdict (a response or a typed [`ServeError`]); nothing hangs,
//!   nothing is silently dropped;
//! * **zero duplicated requests** — server-side counters match the
//!   client-side tallies class for class: `completed` == Ok verdicts,
//!   `rejected` == `QueueFull`, `deadline_shed` == `DeadlineExceeded`,
//!   `panicked` == `WorkerPanicked`;
//! * **panic isolation** — an injected panic costs exactly its batch
//!   (typed failure, no worker-thread death, no process abort);
//! * **quarantined swaps** — a broken candidate is rejected while the
//!   incumbent keeps serving; a good one bumps the version, and every
//!   response carries a valid, per-thread-monotonic version.

use std::sync::{Arc, Barrier};
use tablenet::config::ServeConfig;
use tablenet::coordinator::faults::{
    silence_injected_panics, FaultInjector, FaultPlan, InjectedPanic,
};
use tablenet::coordinator::registry::ModelRegistry;
use tablenet::coordinator::router::RouteError;
use tablenet::coordinator::{Backend, InferOutput, ServeError};
use tablenet::engine::counters::Counters;

/// Instant echo backend: class = image[0] as usize.
struct Echo;

impl Backend for Echo {
    fn infer_batch(&self, images: &[Vec<f32>]) -> Vec<InferOutput> {
        images
            .iter()
            .map(|img| InferOutput {
                class: img[0] as usize,
                logits: vec![img[0], -img[0]],
                counters: Counters { lut_evals: 1, ..Default::default() },
            })
            .collect()
    }

    fn input_features(&self) -> Option<usize> {
        Some(1)
    }

    fn name(&self) -> &'static str {
        "echo"
    }
}

/// A candidate build that panics on every batch — must never survive
/// quarantine.
struct Exploding;

impl Backend for Exploding {
    fn infer_batch(&self, _images: &[Vec<f32>]) -> Vec<InferOutput> {
        std::panic::panic_any(InjectedPanic)
    }

    fn input_features(&self) -> Option<usize> {
        Some(1)
    }

    fn name(&self) -> &'static str {
        "exploding"
    }
}

#[derive(Default)]
struct Tally {
    ok: u64,
    queue_full: u64,
    deadline: u64,
    panicked: u64,
}

#[test]
fn chaos_soak_loses_nothing_and_duplicates_nothing() {
    silence_injected_panics();
    const CLIENTS: usize = 4;
    const PER_CLIENT: usize = 250;
    const MODELS: [&str; 2] = ["a", "b"];

    let plan = FaultPlan::parse("seed=42,latency_prob=0.15,latency_us=500,panic_prob=0.08")
        .unwrap();
    let reg = ModelRegistry::with_faults(Arc::new(FaultInjector::new(plan)));
    let cfg = ServeConfig {
        max_batch: 4,
        max_wait_us: 200,
        workers: 2,
        queue_cap: 16,
        deadline_us: 3_000,
        degrade_after: 0,
        ..ServeConfig::default()
    };
    reg.register("a", Arc::new(Echo), &cfg).unwrap();
    reg.register("b", Arc::new(Echo), &cfg).unwrap();

    // clients rendezvous at half-load so the swaps land mid-soak
    let barrier = Arc::new(Barrier::new(CLIENTS + 1));
    let mut joins = Vec::new();
    for _ in 0..CLIENTS {
        let client = reg.client();
        let barrier = barrier.clone();
        joins.push(std::thread::spawn(move || {
            let mut tally = Tally::default();
            // versions seen per model: a pipeline's version is
            // monotonic, and each blocking request completes before the
            // next is submitted, so a thread must never observe a
            // version going backwards
            let mut last_version = [0u64; 2];
            for i in 0..PER_CLIENT {
                if i == PER_CLIENT / 2 {
                    barrier.wait();
                }
                let m = i % 2;
                let row = vec![(i % 7) as f32];
                let result = if i % 3 == 0 {
                    client.try_infer(MODELS[m], row) // fail-fast path
                } else {
                    client.infer(MODELS[m], row) // blocking path
                };
                match result {
                    Ok(resp) => {
                        tally.ok += 1;
                        assert!(
                            resp.version >= last_version[m],
                            "model '{}' version went backwards: {} after {}",
                            MODELS[m],
                            resp.version,
                            last_version[m]
                        );
                        last_version[m] = resp.version;
                    }
                    Err(RouteError::Submit(ServeError::QueueFull)) => tally.queue_full += 1,
                    Err(RouteError::Submit(ServeError::DeadlineExceeded { .. })) => {
                        tally.deadline += 1;
                    }
                    Err(RouteError::Submit(ServeError::WorkerPanicked)) => {
                        tally.panicked += 1;
                    }
                    Err(other) => panic!("untyped verdict escaped the soak: {other}"),
                }
            }
            (tally, last_version)
        }));
    }

    // mid-soak control-plane activity: a healthy quarantined swap of
    // 'a' (installs v2) and a broken candidate for 'b' (rejected, the
    // incumbent keeps serving at v1)
    barrier.wait();
    assert_eq!(reg.swap_quarantined("a", Arc::new(Echo)).unwrap(), 2);
    assert!(reg.swap_quarantined("b", Arc::new(Exploding)).is_err());

    let mut total = Tally::default();
    for j in joins {
        let (t, last_version) = j.join().unwrap();
        total.ok += t.ok;
        total.queue_full += t.queue_full;
        total.deadline += t.deadline;
        total.panicked += t.panicked;
        assert!(last_version[0] <= 2, "model 'a' never had a version past 2");
        assert!(last_version[1] <= 1, "model 'b' must stay at v1");
    }

    // zero lost: every request produced exactly one verdict
    let submitted = (CLIENTS * PER_CLIENT) as u64;
    assert_eq!(
        total.ok + total.queue_full + total.deadline + total.panicked,
        submitted,
        "verdicts do not account for every submitted request"
    );
    // the fault plan actually fired: injected panics surfaced as typed
    // WorkerPanicked verdicts (>=250 batches at panic_prob 0.08)
    assert!(total.panicked > 0, "no injected panic surfaced in {submitted} requests");

    let infos = reg.models();
    assert_eq!((infos[0].name.as_str(), infos[0].version), ("a", 2));
    assert_eq!((infos[1].name.as_str(), infos[1].version), ("b", 1));

    // zero duplicated: the server counted each request exactly once, in
    // exactly the class the client observed
    let fleet = reg.shutdown();
    assert_eq!(fleet.completed(), total.ok, "completed != Ok verdicts");
    assert_eq!(fleet.rejected(), total.queue_full, "rejected != QueueFull verdicts");
    assert_eq!(fleet.deadline_shed(), total.deadline, "shed != DeadlineExceeded verdicts");
    assert_eq!(fleet.panicked(), total.panicked, "panicked != WorkerPanicked verdicts");
    assert_eq!(fleet.swaps(), 1, "only the quarantine-passing swap may install");
    fleet.assert_multiplier_less();
}

#[test]
fn injected_panics_latch_degraded_and_a_swap_clears_it() {
    silence_injected_panics();
    let plan = FaultPlan::parse("seed=9,panic_prob=1").unwrap();
    let reg = ModelRegistry::with_faults(Arc::new(FaultInjector::new(plan)));
    let cfg = ServeConfig {
        max_batch: 1,
        max_wait_us: 50,
        workers: 1,
        queue_cap: 8,
        deadline_us: 0,
        degrade_after: 2,
        ..ServeConfig::default()
    };
    reg.register("m", Arc::new(Echo), &cfg).unwrap();
    let client = reg.client();
    for _ in 0..3 {
        match client.infer("m", vec![1.0]) {
            Err(RouteError::Submit(ServeError::WorkerPanicked)) => {}
            other => panic!("expected WorkerPanicked, got {other:?}"),
        }
    }
    let fleet = reg.fleet();
    assert_eq!(fleet.degraded(), vec!["m"], "2 consecutive panics must latch Degraded");
    assert_eq!(fleet.models["m"].stats.panicked, 3);
    // the panic perimeter is per batch: the worker thread never died
    assert_eq!(fleet.models["m"].stats.worker_restarts, 0);

    // a quarantined swap installs a fresh backend and clears the latch
    // (the golden self-check runs on the control plane, outside the
    // fault injector's reach)
    assert_eq!(reg.swap_quarantined("m", Arc::new(Echo)).unwrap(), 2);
    assert!(reg.fleet().degraded().is_empty(), "a swap must clear the Degraded latch");
    reg.shutdown();
}

#[test]
fn saturation_with_deadlines_sheds_cleanly_not_silently() {
    silence_injected_panics();
    // every batch sleeps 4ms; requests carry a 2ms deadline — under 40
    // queued requests on one worker, most of the queue MUST shed, and
    // each shed must be a typed DeadlineExceeded that waited at least
    // the full deadline
    let plan = FaultPlan::parse("seed=3,latency_prob=1,latency_us=4000").unwrap();
    let reg = ModelRegistry::with_faults(Arc::new(FaultInjector::new(plan)));
    let cfg = ServeConfig {
        max_batch: 1,
        max_wait_us: 100,
        workers: 1,
        queue_cap: 2,
        deadline_us: 2_000,
        degrade_after: 0,
        ..ServeConfig::default()
    };
    reg.register("m", Arc::new(Echo), &cfg).unwrap();
    let n_threads = 4u64;
    let per = 10u64;
    let mut joins = Vec::new();
    for _ in 0..n_threads {
        let client = reg.client();
        joins.push(std::thread::spawn(move || {
            let (mut ok, mut shed) = (0u64, 0u64);
            for i in 0..per {
                match client.infer("m", vec![i as f32]) {
                    Ok(_) => ok += 1,
                    Err(RouteError::Submit(ServeError::DeadlineExceeded { waited_us })) => {
                        assert!(waited_us >= 2_000, "shed before its deadline: {waited_us}µs");
                        shed += 1;
                    }
                    other => panic!("untyped/unexpected verdict: {other:?}"),
                }
            }
            (ok, shed)
        }));
    }
    let (mut ok, mut shed) = (0u64, 0u64);
    for j in joins {
        let (o, s) = j.join().unwrap();
        ok += o;
        shed += s;
    }
    assert_eq!(ok + shed, n_threads * per);
    assert!(shed > 0, "a 4ms-per-batch pipeline cannot serve 40 requests inside 2ms each");
    let fleet = reg.shutdown();
    assert_eq!(fleet.completed(), ok);
    assert_eq!(fleet.deadline_shed(), shed);
    fleet.assert_multiplier_less();
}
