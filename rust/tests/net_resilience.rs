//! Resilience tests for the wire edge: graceful drain, idempotency-key
//! replay, auth + per-connection limits, and the kill-and-restart soak.
//!
//! The headline test restarts the server **on the same port, mid-load,
//! with faults injected and a quarantined swap in flight**, while
//! [`ReconnectingClient`]s ride through on their retry budgets. The
//! exactly-once contract under test:
//!
//! * zero lost rows — every row a client sent gets exactly one verdict;
//! * zero duplicate acknowledgements — a reply lost to a drop is
//!   re-fetched under the same idempotency key, never re-acked;
//! * the wire ledger balances on both server incarnations;
//! * per-(model, version) latency sub-histograms stay distinct across
//!   the mid-run swap.
#![cfg(unix)]

use std::sync::Arc;
use std::time::{Duration, Instant};
use tablenet::config::ServeConfig;
use tablenet::coordinator::faults::{silence_injected_panics, FaultInjector, FaultPlan};
use tablenet::coordinator::registry::ModelRegistry;
use tablenet::coordinator::{Backend, InferOutput};
use tablenet::engine::counters::Counters;
use tablenet::net::{
    AdmissionController, Frame, NetClient, NetServer, NetServerOptions, ReconnectingClient,
    RetryPolicy, Status,
};

const FEATURES: u32 = 4;

/// Instant echo backend: class = row[0] as usize.
struct Echo;

impl Backend for Echo {
    fn infer_batch(&self, images: &[Vec<f32>]) -> Vec<InferOutput> {
        images
            .iter()
            .map(|img| InferOutput {
                class: img[0] as usize,
                logits: vec![img[0], -img[0]],
                counters: Counters { lut_evals: 1, ..Default::default() },
            })
            .collect()
    }

    fn input_features(&self) -> Option<usize> {
        Some(FEATURES as usize)
    }

    fn name(&self) -> &'static str {
        "echo"
    }
}

fn serve_one(opts: NetServerOptions) -> (ModelRegistry, Arc<AdmissionController>, NetServer) {
    let reg = ModelRegistry::new();
    reg.register("m", Arc::new(Echo), &ServeConfig::default()).unwrap();
    let admission = Arc::new(AdmissionController::new(0));
    let server = NetServer::start("127.0.0.1:0", reg.client(), admission.clone(), opts).unwrap();
    (reg, admission, server)
}

#[test]
fn idempotency_keys_echo_and_replay_from_cache() {
    let (reg, _admission, server) =
        serve_one(NetServerOptions { threads: 1, ..NetServerOptions::default() });
    let addr = server.local_addr().to_string();

    let mut cl = NetClient::connect(&addr).unwrap();
    cl.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    cl.hello(9, "").unwrap();
    let data = vec![2.0f32; 3 * FEATURES as usize];
    cl.send_keyed(5, "m", FEATURES, &data).unwrap();
    let first = match cl.read_frame().unwrap() {
        Frame::Reply(r) => r,
        other => panic!("expected a reply, got {other:?}"),
    };
    assert_eq!(first.key, 5, "the idempotency key must echo in the reply");
    assert_eq!(first.rows.len(), 3);
    assert!(first.rows.iter().all(|r| r.status == Status::Ok), "{first:?}");

    // the same (client_id, key) again: answered from the replay cache,
    // byte-for-byte, without re-submitting a single row
    cl.send_keyed(5, "m", FEATURES, &data).unwrap();
    let replayed = match cl.read_frame().unwrap() {
        Frame::Reply(r) => r,
        other => panic!("expected the replayed reply, got {other:?}"),
    };
    assert_eq!(replayed, first, "replay must return the original verdicts");

    // an UNKEYED repeat is a fresh submission (key 0 is never cached)
    cl.send("m", FEATURES, &data).unwrap();
    match cl.read_frame().unwrap() {
        Frame::Reply(r) => assert_eq!(r.key, 0),
        other => panic!("expected a reply, got {other:?}"),
    }

    let snap = server.shutdown();
    snap.assert_accounted();
    assert_eq!((snap.frames_replayed, snap.rows_replayed), (1, 3), "{snap:?}");
    assert_eq!(snap.models["m"].rows_admitted, 6, "replays never re-submit");
    assert_eq!(snap.rows_done, 6, "replayed rows must not double-count the ledger");
    reg.shutdown();
}

#[test]
fn auth_gate_admits_the_token_and_fails_everything_else_closed() {
    let (reg, _admission, server) = serve_one(NetServerOptions {
        threads: 1,
        auth_token: Some("sesame".to_string()),
        ..NetServerOptions::default()
    });
    let addr = server.local_addr().to_string();
    let data = vec![1.0f32; FEATURES as usize];

    // no hello at all: the first request is refused and the connection
    // fails closed
    let mut cl = NetClient::connect(&addr).unwrap();
    cl.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    cl.send("m", FEATURES, &data).unwrap();
    match cl.read_frame().unwrap() {
        Frame::Error(e) => assert_eq!(e.status, Status::AuthFailed, "{e:?}"),
        other => panic!("expected AuthFailed, got {other:?}"),
    }
    assert!(cl.read_frame().is_err(), "an unauthed connection must close");

    // wrong token: same typed refusal
    let mut cl = NetClient::connect(&addr).unwrap();
    cl.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    cl.hello(1, "open says me").unwrap();
    match cl.read_frame().unwrap() {
        Frame::Error(e) => assert_eq!(e.status, Status::AuthFailed, "{e:?}"),
        other => panic!("expected AuthFailed, got {other:?}"),
    }

    // the right token serves
    let mut cl = NetClient::connect(&addr).unwrap();
    cl.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    cl.hello(1, "sesame").unwrap();
    match cl.infer("m", FEATURES, &data).unwrap() {
        Frame::Reply(r) => assert_eq!(r.rows[0].status, Status::Ok, "{r:?}"),
        other => panic!("expected a reply, got {other:?}"),
    }

    let snap = server.shutdown();
    snap.assert_accounted();
    assert_eq!(snap.auth_failures, 2, "{snap:?}");
    assert_eq!(snap.rows_ok(), 1);
    reg.shutdown();
}

#[test]
fn per_connection_rate_limit_rejects_typed_and_keeps_the_connection() {
    let (reg, _admission, server) = serve_one(NetServerOptions {
        threads: 1,
        frame_rate_limit: 2,
        ..NetServerOptions::default()
    });
    let addr = server.local_addr().to_string();

    let mut cl = NetClient::connect(&addr).unwrap();
    cl.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let data = vec![1.0f32; 2 * FEATURES as usize];
    const FRAMES: usize = 6;
    for _ in 0..FRAMES {
        cl.send("m", FEATURES, &data).unwrap();
    }
    let (mut ok_frames, mut limited_frames) = (0u64, 0u64);
    for _ in 0..FRAMES {
        match cl.read_frame().unwrap() {
            Frame::Reply(r) => {
                assert!(r.rows.iter().all(|row| row.status == Status::Ok), "{r:?}");
                ok_frames += 1;
            }
            Frame::Error(e) => {
                assert_eq!(e.status, Status::RateLimited, "{e:?}");
                assert!(e.status.is_retryable(), "rate limits must be retryable");
                limited_frames += 1;
            }
            other => panic!("unexpected frame: {other:?}"),
        }
    }
    // burst capacity is one second's worth (2 frames); everything past
    // it inside the same instant is limited. A slow machine may refill
    // a token mid-test, so bound rather than pin the split.
    assert!(ok_frames >= 2, "burst capacity must admit 2 frames, got {ok_frames}");
    assert!(limited_frames >= 1, "the limiter never fired over {FRAMES} instant frames");
    assert_eq!(ok_frames + limited_frames, FRAMES as u64);
    // the connection survived every rejection
    match cl.infer("ghost", FEATURES, &data) {
        Ok(Frame::Error(e)) => {
            assert!(matches!(e.status, Status::UnknownModel | Status::RateLimited), "{e:?}");
        }
        other => panic!("connection must stay open after RateLimited, got {other:?}"),
    }

    let snap = server.shutdown();
    snap.assert_accounted();
    assert_eq!(snap.models["m"].rows_rate_limited, limited_frames * 2, "{snap:?}");
    assert_eq!(snap.models["m"].rows_ok, ok_frames * 2);
    reg.shutdown();
}

#[test]
fn connection_cap_refuses_typed_and_recovers_when_slots_free() {
    let (reg, _admission, server) =
        serve_one(NetServerOptions { threads: 1, max_conns: 1, ..NetServerOptions::default() });
    let addr = server.local_addr().to_string();
    let data = vec![1.0f32; FEATURES as usize];

    let mut first = NetClient::connect(&addr).unwrap();
    first.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    match first.infer("m", FEATURES, &data).unwrap() {
        Frame::Reply(r) => assert_eq!(r.rows[0].status, Status::Ok, "{r:?}"),
        other => panic!("expected a reply, got {other:?}"),
    }

    // a second connection is over the cap: typed refusal, then closed,
    // without the client sending a byte
    let mut second = NetClient::connect(&addr).unwrap();
    second.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    match second.read_frame().unwrap() {
        Frame::Error(e) => {
            assert_eq!(e.status, Status::TooManyConnections, "{e:?}");
            assert!(e.status.is_retryable(), "cap refusals must be retryable");
        }
        other => panic!("expected TooManyConnections, got {other:?}"),
    }
    assert!(second.read_frame().is_err(), "an over-cap connection must close");

    // freeing the slot admits a new connection
    drop(first);
    let t0 = Instant::now();
    while server.active_connections() > 0 {
        assert!(t0.elapsed() < Duration::from_secs(10), "slot never freed");
        std::thread::sleep(Duration::from_millis(5));
    }
    let mut third = NetClient::connect(&addr).unwrap();
    third.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    match third.infer("m", FEATURES, &data).unwrap() {
        Frame::Reply(r) => assert_eq!(r.rows[0].status, Status::Ok, "{r:?}"),
        other => panic!("expected a reply, got {other:?}"),
    }

    let snap = server.shutdown();
    snap.assert_accounted();
    assert_eq!(snap.connections_refused, 1, "{snap:?}");
    reg.shutdown();
}

#[test]
fn drain_sends_goaway_finishes_inflight_and_refuses_new_typed() {
    let (reg, _admission, server) = serve_one(NetServerOptions {
        threads: 1,
        drain_grace_ms: 5_000,
        ..NetServerOptions::default()
    });
    let addr = server.local_addr().to_string();
    let data = vec![1.0f32; FEATURES as usize];

    let mut cl = NetClient::connect(&addr).unwrap();
    cl.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    // the hello upgrades this connection to protocol v2, so it is owed
    // a GoAway when the drain starts
    cl.hello(3, "").unwrap();
    match cl.infer("m", FEATURES, &data).unwrap() {
        Frame::Reply(r) => assert_eq!(r.rows[0].status, Status::Ok, "{r:?}"),
        other => panic!("expected a reply, got {other:?}"),
    }

    server.begin_drain("maintenance window");
    assert!(server.draining());
    match cl.read_frame().unwrap() {
        Frame::GoAway(ga) => {
            assert_eq!(ga.reason, "maintenance window");
            assert_eq!(ga.grace_ms, 5_000);
        }
        other => panic!("expected GoAway, got {other:?}"),
    }
    // requests after the drain notice get a typed retryable refusal
    match cl.infer("m", FEATURES, &data).unwrap() {
        Frame::Error(e) => {
            assert_eq!(e.status, Status::ShutDown, "{e:?}");
            assert!(e.status.is_retryable());
        }
        other => panic!("expected ShutDown, got {other:?}"),
    }

    let snap = server.shutdown();
    snap.assert_accounted();
    assert_eq!(snap.goaways_sent, 1, "{snap:?}");
    assert_eq!(snap.rows_ok(), 1);
    assert_eq!(snap.rows_done, 2, "the drain-refused row is still an answered row");
    reg.shutdown();
}

#[test]
fn drain_signal_handler_latches_sigterm() {
    extern "C" {
        fn raise(sig: i32) -> i32;
    }
    tablenet::net::install_drain_signal_handler();
    assert_eq!(unsafe { raise(15) }, 0); // SIGTERM
    assert!(tablenet::net::drain_signal_received(), "SIGTERM must latch, not kill");
}

#[derive(Default)]
struct Tally {
    ok: u64,
    queue_full: u64,
    deadline: u64,
    panicked: u64,
    shutdown: u64,
    lost: u64,
    dups: u64,
}

/// The headline soak: kill the server mid-load (graceful drain on the
/// same port a restarted incarnation rebinds through `SO_REUSEADDR`),
/// with injected faults and a quarantined swap in flight, while
/// reconnecting clients ride through on their retry budgets.
#[test]
fn kill_and_restart_soak_loses_nothing_and_keeps_versions_distinct() {
    silence_injected_panics();
    const CLIENTS: usize = 3;
    const FRAMES_PER_CLIENT: usize = 30;
    const ROWS_PER_FRAME: usize = 4;
    const TOTAL_ROWS: u64 = (CLIENTS * FRAMES_PER_CLIENT * ROWS_PER_FRAME) as u64;

    let plan =
        FaultPlan::parse("seed=7,latency_prob=0.2,latency_us=400,panic_prob=0.05").unwrap();
    let reg = ModelRegistry::with_faults(Arc::new(FaultInjector::new(plan)));
    let cfg = ServeConfig {
        max_batch: 4,
        max_wait_us: 200,
        workers: 2,
        queue_cap: 64,
        deadline_us: 100_000,
        degrade_after: 0,
        ..ServeConfig::default()
    };
    reg.register("m", Arc::new(Echo), &cfg).unwrap();
    let admission = Arc::new(AdmissionController::new(0));
    let opts = NetServerOptions {
        threads: 2,
        drain_grace_ms: 2_000,
        ..NetServerOptions::default()
    };

    let server1 =
        NetServer::start("127.0.0.1:0", reg.client(), admission.clone(), opts.clone()).unwrap();
    let addr = server1.local_addr().to_string();

    let mut joins = Vec::new();
    for c in 0..CLIENTS {
        let addr = addr.clone();
        joins.push(std::thread::spawn(move || {
            let policy = RetryPolicy {
                budget: 256,
                refill_per_sec: 32.0,
                base: Duration::from_millis(5),
                cap: Duration::from_millis(100),
                seed: 0xd1ce ^ (c as u64),
                read_timeout: Some(Duration::from_secs(10)),
            };
            let mut cl = ReconnectingClient::new(&addr, 100 + c as u64, "", policy);
            let mut tally = Tally::default();
            for i in 0..FRAMES_PER_CLIENT {
                let class = (i % 7) as f32;
                let mut data = vec![0.0f32; ROWS_PER_FRAME * FEATURES as usize];
                for r in 0..ROWS_PER_FRAME {
                    data[r * FEATURES as usize] = class;
                }
                let reply = cl
                    .infer("m", FEATURES, &data)
                    .unwrap_or_else(|e| panic!("[conn {c}] frame {i} unresolved: {e}"));
                tally.lost +=
                    (ROWS_PER_FRAME.saturating_sub(reply.rows.len())) as u64;
                tally.dups +=
                    (reply.rows.len().saturating_sub(ROWS_PER_FRAME)) as u64;
                for row in reply.rows.iter().take(ROWS_PER_FRAME) {
                    match row.status {
                        Status::Ok => {
                            tally.ok += 1;
                            assert_eq!(row.class, class as u16, "echo must round-trip");
                            assert!(
                                (1..=2).contains(&row.version),
                                "impossible version {}",
                                row.version
                            );
                        }
                        Status::QueueFull => tally.queue_full += 1,
                        Status::DeadlineExceeded => tally.deadline += 1,
                        Status::WorkerPanicked => tally.panicked += 1,
                        Status::ShutDown => tally.shutdown += 1,
                        other => panic!("untyped verdict escaped the soak: {other}"),
                    }
                }
            }
            (tally, cl.stats())
        }));
    }

    // phase 1: at quarter-load, hot-swap the model (v2 installs after
    // its quarantine batch passes)
    let wait_rows = |server: &NetServer, target: u64, what: &str| {
        let t0 = Instant::now();
        while server.rows_done() < target {
            assert!(t0.elapsed() < Duration::from_secs(60), "soak stalled before {what}");
            std::thread::sleep(Duration::from_millis(2));
        }
    };
    wait_rows(&server1, TOTAL_ROWS / 4, "the mid-run swap");
    assert_eq!(reg.swap_quarantined("m", Arc::new(Echo)).unwrap(), 2);

    // phase 2: at half-load, gracefully drain incarnation one and
    // restart on the SAME port — SO_REUSEADDR must carry the rebind
    // through the drained connections' TIME_WAIT
    wait_rows(&server1, TOTAL_ROWS / 2, "the restart");
    server1.begin_drain("rolling restart");
    let snap1 = server1.shutdown();
    snap1.assert_accounted();
    let server2 = NetServer::start(&addr, reg.client(), admission.clone(), opts).unwrap();

    let mut total = Tally::default();
    let mut connects = 0u64;
    let mut retries = 0u64;
    let mut goaways = 0u64;
    for j in joins {
        let (t, stats) = j.join().unwrap();
        total.ok += t.ok;
        total.queue_full += t.queue_full;
        total.deadline += t.deadline;
        total.panicked += t.panicked;
        total.shutdown += t.shutdown;
        total.lost += t.lost;
        total.dups += t.dups;
        connects += stats.connects;
        retries += stats.retries;
        goaways += stats.goaways_seen;
    }

    // the exactly-once contract, client side
    assert_eq!(total.lost, 0, "rows lost: sent but never answered");
    assert_eq!(total.dups, 0, "duplicate row acknowledgements: exactly-once violated");
    assert_eq!(
        total.ok + total.queue_full + total.deadline + total.panicked + total.shutdown,
        TOTAL_ROWS,
        "client verdicts do not account for every row sent"
    );
    assert!(connects >= CLIENTS as u64 + 1, "nobody reconnected across the restart");
    assert!(retries >= 1, "the restart must have cost at least one retry token");
    assert!(goaways >= 1, "no client observed the GoAway drain notice");

    // both incarnations balance their wire ledgers independently
    let snap2 = server2.shutdown();
    snap2.assert_accounted();
    assert!(snap1.goaways_sent >= 1, "{snap1:?}");
    assert!(
        snap2.models.get("m").is_some_and(|m| m.rows_admitted > 0),
        "the restarted server saw no traffic: {snap2:?}"
    );
    assert_eq!(snap2.admission.in_flight, 0, "admission tokens leaked: {:?}", snap2.admission);
    // server-side Ok acks can exceed the client's (a reply dropped at
    // the drain boundary is re-executed by the fresh incarnation) but
    // can never undercount an acknowledged row
    assert!(snap1.rows_ok() + snap2.rows_ok() >= total.ok);

    // swap-aware histograms: v1 and v2 kept distinct sub-histograms
    // instead of averaging into one aggregate
    let rows_at = |snap: &tablenet::net::NetSnapshot, v: u64| -> u64 {
        snap.versions.get("m").and_then(|m| m.get(&v)).map_or(0, |s| s.rows)
    };
    assert!(rows_at(&snap1, 1) > 0, "no v1 rows recorded before the swap: {snap1:?}");
    assert!(
        rows_at(&snap1, 2) + rows_at(&snap2, 2) > 0,
        "no v2 rows recorded after the swap"
    );

    let fleet = reg.shutdown();
    assert_eq!(fleet.swaps(), 1);
    fleet.assert_multiplier_less();
}
