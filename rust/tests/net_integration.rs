//! Socket-level integration tests for the network serving tier.
//!
//! Everything here drives a real [`NetServer`] over loopback TCP and
//! asserts the wire contract end to end:
//!
//! * **typed failure, fail closed** — malformed, truncated and
//!   oversized frames are answered with a [`Status::Malformed`] error
//!   frame and the connection closes; the server (and its accounting)
//!   survives;
//! * **zero lost / zero duplicated** — a multi-connection soak with a
//!   mid-run quarantined swap and injected faults accounts for every
//!   row exactly once, and the wire-boundary counters match both the
//!   client tallies and the pipeline's own fleet snapshot class for
//!   class;
//! * **admission backpressure** — when the shared budget is exhausted,
//!   whole frames are refused with a typed queue-full-class error
//!   ([`Status::AdmissionRejected`]) and nothing leaks in flight.
#![cfg(unix)]

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;
use tablenet::config::ServeConfig;
use tablenet::coordinator::faults::{
    silence_injected_panics, FaultInjector, FaultPlan, InjectedPanic,
};
use tablenet::coordinator::registry::ModelRegistry;
use tablenet::coordinator::{Backend, InferOutput};
use tablenet::engine::counters::Counters;
use tablenet::net::proto::{decode_payload, encode_frame};
use tablenet::net::{
    AdmissionController, Frame, NetClient, NetServer, NetServerOptions, Status,
};

const FEATURES: u32 = 4;

/// Instant echo backend: class = row[0] as usize.
struct Echo;

impl Backend for Echo {
    fn infer_batch(&self, images: &[Vec<f32>]) -> Vec<InferOutput> {
        images
            .iter()
            .map(|img| InferOutput {
                class: img[0] as usize,
                logits: vec![img[0], -img[0]],
                counters: Counters { lut_evals: 1, ..Default::default() },
            })
            .collect()
    }

    fn input_features(&self) -> Option<usize> {
        Some(FEATURES as usize)
    }

    fn name(&self) -> &'static str {
        "echo"
    }
}

/// Echo that also sleeps per batch, to hold admission tokens in flight.
struct SlowEcho(Duration);

impl Backend for SlowEcho {
    fn infer_batch(&self, images: &[Vec<f32>]) -> Vec<InferOutput> {
        std::thread::sleep(self.0);
        Echo.infer_batch(images)
    }

    fn input_features(&self) -> Option<usize> {
        Some(FEATURES as usize)
    }

    fn name(&self) -> &'static str {
        "slow-echo"
    }
}

/// A candidate build that panics on every batch — must never survive
/// quarantine.
struct Exploding;

impl Backend for Exploding {
    fn infer_batch(&self, _images: &[Vec<f32>]) -> Vec<InferOutput> {
        std::panic::panic_any(InjectedPanic)
    }

    fn input_features(&self) -> Option<usize> {
        Some(FEATURES as usize)
    }

    fn name(&self) -> &'static str {
        "exploding"
    }
}

/// Write one raw length-prefixed frame (payload supplied verbatim).
fn write_raw(stream: &mut TcpStream, payload: &[u8]) {
    let mut buf = Vec::with_capacity(4 + payload.len());
    buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    buf.extend_from_slice(payload);
    stream.write_all(&buf).unwrap();
}

/// Read one frame off a raw stream; `None` on clean EOF.
fn read_raw(stream: &mut TcpStream) -> Option<Frame> {
    let mut len = [0u8; 4];
    if stream.read_exact(&mut len).is_err() {
        return None;
    }
    let mut payload = vec![0u8; u32::from_le_bytes(len) as usize];
    stream.read_exact(&mut payload).unwrap();
    Some(decode_payload(&payload).unwrap())
}

/// A well-formed request payload (length prefix stripped) for slicing
/// into truncated variants.
fn request_payload(model: &str, rows: usize) -> Vec<u8> {
    let req = tablenet::net::InferRequest {
        key: 0,
        model: model.to_string(),
        features: FEATURES,
        data: vec![0.5; rows * FEATURES as usize],
    };
    let mut framed = Vec::new();
    encode_frame(&Frame::Request(req), &mut framed);
    framed.split_off(4)
}

fn expect_error(frame: Option<Frame>, status: Status) {
    match frame {
        Some(Frame::Error(e)) => assert_eq!(e.status, status, "{e:?}"),
        other => panic!("expected a typed {status} error frame, got {other:?}"),
    }
}

#[test]
fn malformed_frames_get_typed_errors_and_fail_closed() {
    let reg = ModelRegistry::new();
    reg.register("m", Arc::new(Echo), &ServeConfig::default()).unwrap();
    let admission = Arc::new(AdmissionController::new(0));
    let server = NetServer::start(
        "127.0.0.1:0",
        reg.client(),
        admission,
        NetServerOptions { threads: 2, ..NetServerOptions::default() },
    )
    .unwrap();
    let addr = server.local_addr().to_string();
    let connect = || {
        let s = TcpStream::connect(&addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        s
    };

    // bad magic: typed Malformed error, then the connection closes
    let mut s = connect();
    write_raw(&mut s, b"XXXX\x01\x01");
    expect_error(read_raw(&mut s), Status::Malformed);
    assert!(read_raw(&mut s).is_none(), "a protocol error must close the connection");

    // truncated body (length prefix consistent, structure short)
    let mut s = connect();
    let payload = request_payload("m", 2);
    write_raw(&mut s, &payload[..payload.len() - 3]);
    expect_error(read_raw(&mut s), Status::Malformed);
    assert!(read_raw(&mut s).is_none());

    // oversized length prefix: refused without buffering the body
    let mut s = connect();
    s.write_all(&(((1u32 << 24) + 1).to_le_bytes())).unwrap();
    expect_error(read_raw(&mut s), Status::Malformed);
    assert!(read_raw(&mut s).is_none());

    // a reply frame in the client->server direction is also a violation
    let mut s = connect();
    let mut framed = Vec::new();
    encode_frame(
        &Frame::Reply(tablenet::net::InferReply { key: 0, rows: Vec::new() }),
        &mut framed,
    );
    s.write_all(&framed).unwrap();
    expect_error(read_raw(&mut s), Status::Malformed);
    assert!(read_raw(&mut s).is_none());

    // an unknown model is a typed error but NOT a protocol violation:
    // the connection stays usable
    let mut cl = NetClient::connect(&addr).unwrap();
    cl.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    match cl.infer("ghost", FEATURES, &[0.5; 4]).unwrap() {
        Frame::Error(e) => assert_eq!(e.status, Status::UnknownModel, "{e:?}"),
        other => panic!("expected UnknownModel, got {other:?}"),
    }
    match cl.infer("m", FEATURES, &[3.0, 0.0, 0.0, 0.0]).unwrap() {
        Frame::Reply(r) => {
            assert_eq!(r.rows.len(), 1);
            assert_eq!((r.rows[0].status, r.rows[0].class), (Status::Ok, 3));
        }
        other => panic!("expected a reply, got {other:?}"),
    }

    let snap = server.shutdown();
    snap.assert_accounted();
    assert_eq!(snap.protocol_errors, 4, "{snap:?}");
    assert_eq!(snap.unknown_model_frames, 1);
    assert_eq!(snap.rows_ok(), 1);
    // frame-level rejections still count as answered rows — nothing
    // vanished from the wire ledger
    assert_eq!(snap.rows_done, 2, "{snap:?}");
    reg.shutdown().assert_multiplier_less();
}

#[derive(Default)]
struct Tally {
    ok: u64,
    queue_full: u64,
    deadline: u64,
    panicked: u64,
}

#[test]
fn socket_soak_with_midrun_swap_and_faults_loses_nothing() {
    silence_injected_panics();
    const CLIENTS: usize = 4;
    const FRAMES_PER_CLIENT: usize = 60;
    const ROWS_PER_FRAME: usize = 5;
    const TOTAL_ROWS: u64 = (CLIENTS * FRAMES_PER_CLIENT * ROWS_PER_FRAME) as u64;

    let plan = FaultPlan::parse("seed=42,latency_prob=0.15,latency_us=500,panic_prob=0.08")
        .unwrap();
    let reg = ModelRegistry::with_faults(Arc::new(FaultInjector::new(plan)));
    let cfg = ServeConfig {
        max_batch: 4,
        max_wait_us: 200,
        workers: 2,
        queue_cap: 64,
        deadline_us: 50_000,
        degrade_after: 0,
        ..ServeConfig::default()
    };
    reg.register("a", Arc::new(Echo), &cfg).unwrap();
    reg.register("b", Arc::new(Echo), &cfg).unwrap();

    let admission = Arc::new(AdmissionController::new(0));
    let server = NetServer::start(
        "127.0.0.1:0",
        reg.client(),
        admission,
        NetServerOptions { threads: 2, ..NetServerOptions::default() },
    )
    .unwrap();
    let addr = server.local_addr().to_string();

    let mut joins = Vec::new();
    for c in 0..CLIENTS {
        let addr = addr.clone();
        joins.push(std::thread::spawn(move || {
            let mut cl = NetClient::connect_retry(&addr, 5_000).unwrap();
            cl.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
            let mut tally = Tally::default();
            let mut last_version = [0u64; 2];
            for i in 0..FRAMES_PER_CLIENT {
                let m = (c + i) % 2;
                let model = ["a", "b"][m];
                let class = (i % 7) as f32;
                let mut data = vec![0.0f32; ROWS_PER_FRAME * FEATURES as usize];
                for r in 0..ROWS_PER_FRAME {
                    data[r * FEATURES as usize] = class;
                }
                let reply = match cl.infer(model, FEATURES, &data).unwrap() {
                    Frame::Reply(r) => r,
                    other => panic!("unexpected frame mid-soak: {other:?}"),
                };
                assert_eq!(reply.rows.len(), ROWS_PER_FRAME, "no row may go unanswered");
                for row in reply.rows {
                    match row.status {
                        Status::Ok => {
                            tally.ok += 1;
                            assert_eq!(row.class, class as u16, "echo must round-trip");
                            assert_eq!(row.logits.len(), 2);
                            assert!(
                                row.version >= last_version[m],
                                "model '{model}' version went backwards: {} after {}",
                                row.version,
                                last_version[m]
                            );
                            last_version[m] = row.version;
                        }
                        Status::QueueFull => tally.queue_full += 1,
                        Status::DeadlineExceeded => tally.deadline += 1,
                        Status::WorkerPanicked => tally.panicked += 1,
                        other => panic!("untyped verdict escaped the soak: {other}"),
                    }
                }
            }
            (tally, last_version)
        }));
    }

    // mid-soak control plane: a healthy quarantined swap of 'a' and a
    // broken candidate for 'b' (rejected; the incumbent keeps serving)
    let t0 = std::time::Instant::now();
    while server.rows_done() < TOTAL_ROWS / 2 {
        assert!(t0.elapsed() < Duration::from_secs(60), "soak stalled before half-load");
        std::thread::sleep(Duration::from_millis(2));
    }
    assert_eq!(reg.swap_quarantined("a", Arc::new(Echo)).unwrap(), 2);
    assert!(reg.swap_quarantined("b", Arc::new(Exploding)).is_err());

    let mut total = Tally::default();
    for j in joins {
        let (t, last_version) = j.join().unwrap();
        total.ok += t.ok;
        total.queue_full += t.queue_full;
        total.deadline += t.deadline;
        total.panicked += t.panicked;
        assert!(last_version[0] <= 2, "model 'a' never had a version past 2");
        assert!(last_version[1] <= 1, "model 'b' must stay at v1");
    }

    // zero lost, zero duplicated — client side
    assert_eq!(
        total.ok + total.queue_full + total.deadline + total.panicked,
        TOTAL_ROWS,
        "client verdicts do not account for every row sent"
    );
    assert!(total.panicked > 0, "no injected panic surfaced in {TOTAL_ROWS} rows");

    // wire boundary: every admitted row has exactly one verdict, and the
    // totals match the client tallies class for class
    let snap = server.shutdown();
    snap.assert_accounted();
    assert_eq!(snap.rows_done, TOTAL_ROWS);
    assert_eq!(snap.rows_ok(), total.ok);
    let by = |f: fn(&tablenet::net::ModelIngress) -> u64| -> u64 {
        snap.models.values().map(f).sum()
    };
    assert_eq!(by(|m| m.rows_queue_full), total.queue_full);
    assert_eq!(by(|m| m.rows_deadline_shed), total.deadline);
    assert_eq!(by(|m| m.rows_panicked), total.panicked);
    assert_eq!(by(|m| m.rows_admitted), TOTAL_ROWS, "unlimited budget admits everything");
    assert_eq!(snap.admission.in_flight, 0, "admission tokens leaked: {:?}", snap.admission);
    assert_eq!(snap.connections_accepted, CLIENTS as u64);
    assert_eq!(snap.connections_closed, CLIENTS as u64);

    // pipeline boundary: the registry's own counters agree too, so the
    // socket tier introduced no second source of truth
    let fleet = reg.shutdown();
    assert_eq!(fleet.completed(), total.ok);
    assert_eq!(fleet.rejected(), total.queue_full);
    assert_eq!(fleet.deadline_shed(), total.deadline);
    assert_eq!(fleet.panicked(), total.panicked);
    assert_eq!(fleet.swaps(), 1, "only the quarantine-passing swap may install");
    fleet.assert_multiplier_less();
}

#[test]
fn exhausted_admission_budget_rejects_whole_frames_typed() {
    // budget of 4 rows; the backend holds each batch for 50ms, so the
    // first 4-row frame owns the whole budget while two more arrive
    let reg = ModelRegistry::new();
    let cfg = ServeConfig {
        max_batch: 4,
        max_wait_us: 100,
        workers: 1,
        queue_cap: 64,
        deadline_us: 0,
        degrade_after: 0,
        ..ServeConfig::default()
    };
    reg.register("m", Arc::new(SlowEcho(Duration::from_millis(50))), &cfg).unwrap();
    let admission = Arc::new(AdmissionController::new(4));
    let server = NetServer::start(
        "127.0.0.1:0",
        reg.client(),
        admission,
        NetServerOptions { threads: 1, ..NetServerOptions::default() },
    )
    .unwrap();

    let mut cl = NetClient::connect(&server.local_addr().to_string()).unwrap();
    cl.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let data = vec![1.0f32; 4 * FEATURES as usize];
    // pipeline three 4-row frames without reading: the reactor decodes
    // them back-to-back while the budget is pinned by frame one
    for _ in 0..3 {
        cl.send("m", FEATURES, &data).unwrap();
    }
    cl.finish_writes().unwrap();

    let (mut ok_rows, mut rejected_rows) = (0u64, 0u64);
    for _ in 0..3 {
        match cl.read_frame().unwrap() {
            Frame::Reply(r) => {
                assert_eq!(r.rows.len(), 4);
                assert!(r.rows.iter().all(|row| row.status == Status::Ok), "{r:?}");
                ok_rows += r.rows.len() as u64;
            }
            Frame::Error(e) => {
                assert_eq!(e.status, Status::AdmissionRejected, "{e:?}");
                assert!(e.status.is_queue_full_class(), "rejects must be retryable");
                rejected_rows += 4;
            }
            other => panic!("unexpected frame: {other:?}"),
        }
    }
    assert_eq!((ok_rows, rejected_rows), (4, 8), "exactly one frame fits the budget");

    let snap = server.shutdown();
    snap.assert_accounted();
    assert_eq!(snap.rows_done, 12, "rejected rows are still answered rows");
    assert_eq!(snap.models["m"].rows_admitted, 4);
    assert_eq!(snap.models["m"].rows_admission_rejected, 8);
    assert_eq!(snap.admission.in_flight, 0, "admission tokens leaked: {:?}", snap.admission);
    reg.shutdown().assert_multiplier_less();
}
