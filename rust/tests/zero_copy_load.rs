//! Zero-copy load discipline of the v2 artifact: `LutModel::load` of a
//! memory-mapped v2 `.ltm` must perform ZERO table-payload copies —
//! the arenas borrow their entry blocks straight out of the mapping,
//! so heap traffic during load is bounded by metadata (plan JSON,
//! offsets, biases), not by bank size. A v1 artifact of the same model
//! must still load — through the copying path — bit-exact.
//!
//! Enforced for real with a byte-counting global allocator: this test
//! file is its own crate, so the `#[global_allocator]` below only
//! governs this binary, and exactly one test lives here so the counter
//! observes only the code under test.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use tablenet::engine::plan::{AffineMode, EnginePlan};
use tablenet::engine::{artifact, Compiler, LutModel};
use tablenet::nn::Model;
use tablenet::tensor::Tensor;
use tablenet::util::Rng;

static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn alloc_bytes_during(f: impl FnOnce() -> LutModel) -> (LutModel, u64) {
    let before = ALLOC_BYTES.load(Ordering::SeqCst);
    let model = f();
    let after = ALLOC_BYTES.load(Ordering::SeqCst);
    (model, after - before)
}

#[test]
fn v2_mmap_load_copies_no_table_payloads() {
    // ~1 MB of i32 arena: 784/8 = 98 chunks x 2^8 rows x 10 outputs
    let mut rng = Rng::new(0x2E80);
    let model = Model::linear(
        Tensor::randn(&[10, 784], 0.05, &mut rng),
        Tensor::randn(&[10], 0.02, &mut rng),
    );
    let plan = EnginePlan {
        affine: vec![AffineMode::BitplaneFixed { bits: 3, m: 8, range_exp: 0 }],
        fallback: AffineMode::Float { planes: 11, m: 1 },
        r_o: 16,
    };
    let lut = Compiler::new(&model).plan(&plan).build().unwrap();
    let table_bytes = lut.storage_summary().bytes as u64;
    assert!(table_bytes > 500_000, "arena too small to measure: {table_bytes}");

    let dir = std::env::temp_dir().join("tablenet_zero_copy_load");
    std::fs::create_dir_all(&dir).unwrap();
    let p_v2 = dir.join("model_v2.ltm");
    let p_v1 = dir.join("model_v1.ltm");
    lut.save(&p_v2).unwrap();
    std::fs::write(&p_v1, artifact::to_bytes_v1(&lut)).unwrap();

    // v2 serving load: the file maps, the arenas borrow — table bytes
    // never touch the heap. Metadata (plan JSON, offsets, biases) is
    // all that allocates, far below the arena size.
    let (v2, v2_alloc) = alloc_bytes_during(|| LutModel::load(&p_v2).unwrap());
    #[cfg(unix)]
    {
        let s = v2.storage_summary();
        assert!(s.banks > 0);
        assert_eq!(
            s.borrowed, s.banks,
            "every arena of a mapped v2 artifact must be borrowed: {s:?}"
        );
        assert!(
            v2_alloc < table_bytes / 4,
            "v2 mmap load allocated {v2_alloc} bytes — table payloads \
             ({table_bytes} bytes) were copied"
        );
    }

    // v1 legacy load: same loader entry point, copying path — the heap
    // receives (at least) the full arena
    let (v1, v1_alloc) = alloc_bytes_during(|| LutModel::load(&p_v1).unwrap());
    let s = v1.storage_summary();
    assert_eq!(s.borrowed, 0, "v1 artifacts have nothing to borrow from: {s:?}");
    assert!(
        v1_alloc >= table_bytes,
        "v1 copying load allocated only {v1_alloc} bytes for {table_bytes} of tables"
    );

    // both paths are bit-exact with the in-memory compiled model
    let x: Vec<f32> = (0..784).map(|_| rng.f32()).collect();
    let want = lut.infer(&x);
    for (tag, loaded) in [("v2", &v2), ("v1", &v1)] {
        let got = loaded.infer(&x);
        assert_eq!(got.class, want.class, "{tag} class diverged");
        assert_eq!(got.logits, want.logits, "{tag} logits diverged");
        assert_eq!(got.counters, want.counters, "{tag} counters diverged");
    }

    // the mapped model keeps serving after its file is replaced — the
    // deploy watcher relies on this (standard rolling-deploy contract)
    std::fs::remove_file(&p_v2).unwrap();
    let again = v2.infer(&x);
    assert_eq!(again.class, want.class);

    std::fs::remove_dir_all(&dir).ok();
}
