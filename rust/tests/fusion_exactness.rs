//! Stage-folding correctness: a fused pipeline (elementwise chains
//! folded into bank epilogues by `engine::optimize::fold_elementwise`)
//! must be BIT-EXACT with the naive unfused lowering — classes, logits
//! and per-sample counters — across every fusible (model, plan) shape,
//! ragged batch sizes, both forced kernels, and artifact round-trips
//! through both container versions. Plus elementwise boundary-stage
//! edge cases (saturation / rounding / domain clamping) pinned against
//! f64 oracles, identical under scalar and AVX2 dispatch.

use tablenet::engine::act::{ActBuf, Repr};
use tablenet::engine::counters::Counters;
use tablenet::engine::plan::{AffineMode, EnginePlan};
use tablenet::engine::scratch::Scratch;
use tablenet::engine::stages::{SigmoidLutStage, Stage, StageKind, ToFixedStage, ToHalfStage};
use tablenet::engine::{artifact, Compiler, LutModel};
use tablenet::lut::kernel;
use tablenet::lut::scalar::ScalarLut;
use tablenet::nn::Model;
use tablenet::quant::f16::F16;
use tablenet::tensor::Tensor;
use tablenet::util::Rng;

fn mlp_model(rng: &mut Rng) -> Model {
    Model::mlp(vec![
        (Tensor::randn(&[32, 784], 0.05, rng), Tensor::zeros(&[32])),
        (Tensor::randn(&[16, 32], 0.2, rng), Tensor::zeros(&[16])),
        (Tensor::randn(&[10, 16], 0.3, rng), Tensor::zeros(&[10])),
    ])
}

fn sigmoid_model(rng: &mut Rng) -> Model {
    Model {
        arch: tablenet::nn::Arch::Mlp,
        layers: vec![
            tablenet::nn::Layer::Dense {
                w: Tensor::randn(&[24, 784], 0.05, rng),
                b: Tensor::zeros(&[24]),
            },
            tablenet::nn::Layer::Sigmoid,
            tablenet::nn::Layer::Dense {
                w: Tensor::randn(&[10, 24], 0.3, rng),
                b: Tensor::zeros(&[10]),
            },
        ],
        input_shape: vec![784],
    }
}

fn cnn_model(rng: &mut Rng) -> Model {
    Model {
        arch: tablenet::nn::Arch::Cnn,
        layers: vec![
            tablenet::nn::Layer::Conv2d {
                filter: Tensor::randn(&[3, 3, 1, 2], 0.3, rng),
                b: Tensor::randn(&[2], 0.05, rng),
            },
            tablenet::nn::Layer::Relu,
            tablenet::nn::Layer::MaxPool2,
            tablenet::nn::Layer::Conv2d {
                filter: Tensor::randn(&[3, 3, 2, 3], 0.2, rng),
                b: Tensor::randn(&[3], 0.05, rng),
            },
            tablenet::nn::Layer::Relu,
            tablenet::nn::Layer::Flatten,
            tablenet::nn::Layer::Dense {
                w: Tensor::randn(&[10, 4 * 4 * 3], 0.2, rng),
                b: Tensor::zeros(&[10]),
            },
        ],
        input_shape: vec![8, 8, 1],
    }
}

/// Every chain shape the optimizer can fold: `relu+tohalf` (float MLP),
/// `relu+tofixed` (fixed inner layers), `sigmoid+tohalf` (scalar LUT),
/// and the CNN's `conv+relu` before maxpool / `conv+relu+tohalf` after.
fn cases(rng: &mut Rng) -> Vec<(&'static str, Model, EnginePlan)> {
    let float11 = AffineMode::Float { planes: 11, m: 1 };
    vec![
        ("mlp-float", mlp_model(rng), EnginePlan::mlp_default()),
        (
            "mlp-fixed-inner",
            mlp_model(rng),
            EnginePlan {
                affine: vec![
                    AffineMode::WholeFixed { bits: 8, m: 1, range_exp: 0 },
                    AffineMode::BitplaneFixed { bits: 8, m: 4, range_exp: 3 },
                    AffineMode::BitplaneFixed { bits: 8, m: 4, range_exp: 3 },
                ],
                fallback: float11,
                r_o: 16,
            },
        ),
        (
            "sigmoid",
            sigmoid_model(rng),
            EnginePlan { affine: vec![float11, float11], fallback: float11, r_o: 16 },
        ),
        (
            "cnn",
            cnn_model(rng),
            EnginePlan {
                affine: vec![
                    AffineMode::BitplaneFixed { bits: 3, m: 2, range_exp: 0 },
                    float11,
                    float11,
                ],
                fallback: float11,
                r_o: 16,
            },
        ),
    ]
}

fn compile(model: &Model, plan: &EnginePlan, fuse: bool) -> LutModel {
    Compiler::new(model).plan(plan).fuse(fuse).build().unwrap()
}

/// The tentpole property: fused and unfused builds agree bit-exactly —
/// classes, logits, per-sample counters and counter totals — across
/// ragged batches (1..=9 straddles the 4-lane AVX2 width) under BOTH
/// forced kernels, while the fused plan has strictly fewer stages and
/// identical table accounting.
#[test]
fn prop_fused_matches_unfused_bit_exact() {
    let mut rng = Rng::new(0xF05E);
    for (name, model, plan) in cases(&mut rng) {
        let fused = compile(&model, &plan, true);
        let unfused = compile(&model, &plan, false);
        assert!(
            fused.num_stages() < unfused.num_stages(),
            "{name}: fusible plan must get strictly fewer stages \
             ({} vs {})",
            fused.num_stages(),
            unfused.num_stages()
        );
        assert!(
            fused.stages().iter().any(|s| s.fused_chain().is_some()),
            "{name}: expected at least one fused bank"
        );
        assert!(
            unfused.stages().iter().all(|s| s.fused_chain().is_none()),
            "{name}: --no-fuse build must carry no epilogues"
        );
        assert_eq!(fused.size_bits(), unfused.size_bits(), "{name}: table accounting");
        // the pipeline still ends in integer accumulators (terminal
        // chains are trimmed, never folded past the final bank)
        let last = fused.stages().last().unwrap();
        assert!(
            last.fused_chain().is_none_or(|c| c.ends_in_acc()),
            "{name}: terminal epilogue must preserve Acc output"
        );

        let features: usize = model.input_shape.iter().product();
        let mut kernels = vec![kernel::Kernel::Scalar];
        if kernel::avx2_available() {
            kernels.push(kernel::Kernel::Avx2);
        }
        for k in kernels {
            let _g = kernel::force(k);
            for batch in 1..=9usize {
                let images: Vec<f32> =
                    (0..batch * features).map(|_| rng.f32()).collect();
                let mut s1 = Scratch::new();
                let mut s2 = Scratch::new();
                let a = fused.infer_batch(&images, batch, &mut s1);
                let b = unfused.infer_batch(&images, batch, &mut s2);
                a.counters.assert_multiplier_less();
                assert_eq!(a.classes, b.classes, "{name} k={k:?} batch={batch}");
                assert_eq!(a.logits, b.logits, "{name} k={k:?} batch={batch}");
                assert_eq!(
                    a.per_sample, b.per_sample,
                    "{name} k={k:?} batch={batch}: per-sample counters"
                );
                assert_eq!(a.counters, b.counters, "{name} k={k:?} batch={batch}");
            }
        }
    }
}

/// Fused artifacts round-trip through BOTH container versions: the
/// epilogue chain survives save -> load (same kinds on the same banks)
/// and the revived model infers bit-exactly against the in-memory one.
#[test]
fn fused_artifact_roundtrip_both_versions() {
    let mut rng = Rng::new(0xF0A7);
    for (name, model, plan) in cases(&mut rng) {
        let lut = compile(&model, &plan, true);
        let chains: Vec<Option<Vec<StageKind>>> = lut
            .stages()
            .iter()
            .map(|s| s.fused_chain().map(|c| c.kinds()))
            .collect();
        for (ver, bytes) in
            [(2u32, artifact::to_bytes(&lut)), (1u32, artifact::to_bytes_v1(&lut))]
        {
            let back = artifact::from_bytes(&bytes).unwrap();
            assert_eq!(back.num_stages(), lut.num_stages(), "{name} v{ver}");
            let got: Vec<Option<Vec<StageKind>>> = back
                .stages()
                .iter()
                .map(|s| s.fused_chain().map(|c| c.kinds()))
                .collect();
            assert_eq!(got, chains, "{name} v{ver}: epilogue chains diverged");

            let features: usize = model.input_shape.iter().product();
            let batch = 3usize;
            let images: Vec<f32> = (0..batch * features).map(|_| rng.f32()).collect();
            let mut s1 = Scratch::new();
            let mut s2 = Scratch::new();
            let a = lut.infer_batch(&images, batch, &mut s1);
            let b = back.infer_batch(&images, batch, &mut s2);
            assert_eq!(a.classes, b.classes, "{name} v{ver}");
            assert_eq!(a.logits, b.logits, "{name} v{ver}");
            assert_eq!(a.per_sample, b.per_sample, "{name} v{ver}");
        }
    }
}

/// An unfused build writes byte-identical payloads whether or not the
/// epilogue encoding exists: banks without chains append nothing, so
/// `--no-fuse` artifacts stay readable by pre-fusion builds.
#[test]
fn unfused_artifact_carries_no_chain_bytes() {
    let mut rng = Rng::new(0xF0B3);
    let model = mlp_model(&mut rng);
    let lut = compile(&model, &EnginePlan::mlp_default(), false);
    let back = artifact::from_bytes(&artifact::to_bytes(&lut)).unwrap();
    assert!(back.stages().iter().all(|s| s.fused_chain().is_none()));
    // and the inspect metadata agrees: no fused kinds anywhere
    let dir = std::env::temp_dir().join("tablenet_fusion_inspect");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("unfused.ltm");
    lut.save(&path).unwrap();
    let info = artifact::inspect(&path).unwrap();
    assert!(info.stages.iter().all(|s| s.fused.is_empty()));
    std::fs::remove_file(&path).ok();
}

/// Fused inspect metadata names the whole chain: the MLP's interior
/// banks display as `dense-float+relu-int+to-half`.
#[test]
fn inspect_reports_fused_chain_display_names() {
    let mut rng = Rng::new(0xF0C9);
    let model = mlp_model(&mut rng);
    let lut = compile(&model, &EnginePlan::mlp_default(), true);
    let dir = std::env::temp_dir().join("tablenet_fusion_inspect");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("fused.ltm");
    lut.save(&path).unwrap();
    let info = artifact::inspect(&path).unwrap();
    let names: Vec<String> = info.stages.iter().map(|s| s.display_name()).collect();
    assert_eq!(
        names,
        vec![
            "dense-float+relu-int+to-half",
            "dense-float+relu-int+to-half",
            "dense-float",
        ]
    );
    std::fs::remove_file(&path).ok();
}

// ---------------------------------------------------------------------
// Elementwise boundary-stage edge cases. The stages are scalar code, but
// running them under both forced kernels pins that kernel dispatch can
// never change boundary behaviour (the epilogue path runs inside bank
// eval, where the kernel guard is active).
// ---------------------------------------------------------------------

fn with_each_kernel(mut body: impl FnMut()) {
    let mut kernels = vec![kernel::Kernel::Scalar];
    if kernel::avx2_available() {
        kernels.push(kernel::Kernel::Avx2);
    }
    for k in kernels {
        let _g = kernel::force(k);
        body();
    }
}

/// Drive a single elementwise stage over accumulators and return the
/// resulting buffer snapshots.
fn run_on_accs(stage: &dyn Stage, accs: &[i64], frac: u32) -> ActBuf {
    let mut act = ActBuf::new();
    act.load_f32(&vec![0.0; accs.len()], 1);
    act.acc.clear();
    act.acc.extend_from_slice(accs);
    act.set_repr(Repr::Acc(frac));
    let mut scratch = Scratch::new();
    let mut ctrs = vec![Counters::default()];
    stage.eval_batch(&mut act, &mut scratch, &mut ctrs);
    act
}

#[test]
fn tofixed_saturates_and_rounds_at_code_boundaries() {
    with_each_kernel(|| {
        // bits=3, range_exp=0 at frac 16 -> shift 13: codes floor the
        // accumulator, negatives and zero clamp to code 0, anything at
        // or above code 8 saturates to the 7 max code
        let stage = ToFixedStage { bits: 3, range_exp: 0 };
        let accs = [
            i64::MIN,       // deep negative -> 0
            -1,             // -> 0
            0,              // zero is not positive -> 0
            (1 << 13) - 1,  // one below the first boundary -> 0 (floor)
            1 << 13,        // exactly code 1
            (7 << 13) - 1,  // floor keeps 6
            7 << 13,        // top in-range code
            8 << 13,        // first out-of-range value -> saturate 7
            i64::MAX,       // -> saturate 7
        ];
        let act = run_on_accs(&stage, &accs, 16);
        assert_eq!(act.repr(), Repr::Codes(3));
        assert_eq!(act.codes, vec![0, 0, 0, 0, 1, 6, 7, 7, 7]);

        // negative shift (frac 0, bits 8): codes scale UP and must
        // still clamp to the max code instead of overflowing
        let stage = ToFixedStage { bits: 8, range_exp: 0 };
        let act = run_on_accs(&stage, &[1, 2], 0);
        assert_eq!(act.codes, vec![255, 255]);

        // extreme range_exp exercises the +/-63 shift clamp: every
        // positive value shifts to code 0 instead of hitting a masked
        // or overflowing shift amount
        let stage = ToFixedStage { bits: 1, range_exp: 64 };
        let act = run_on_accs(&stage, &[123_456, i64::MAX], 16);
        assert_eq!(act.codes, vec![0, 0]);
    });
}

#[test]
fn tohalf_matches_f64_oracle_on_boundaries() {
    // oracle: ReLU then encode through f64, saturating the overflow
    // to f16 max like the engine does (no infinities in activations)
    fn oracle(a: i64, frac: u32) -> F16 {
        if a <= 0 {
            return F16(0);
        }
        let f = F16::from_f32((a as f64 * (-(frac as f64)).exp2()) as f32);
        if f.0 == 0x7C00 {
            F16(0x7BFF)
        } else {
            f
        }
    }
    with_each_kernel(|| {
        let frac = 16u32;
        let accs = [
            i64::MIN,
            -1,
            0,
            1,                  // subnormal territory
            (1 << 16) - 1,      // just below 1.0
            1 << 16,            // exactly 1.0
            (1 << 16) + 32,     // round-to-even boundary inside the mantissa
            (1 << 16) + 33,     // just past it
            (3 << 15),          // 1.5
            (1 << 31) - 1,      // large, still finite in f16? -> oracle decides
            1 << 37,            // beyond f16 max -> saturates like the oracle
            i64::MAX,
        ];
        let stage = ToHalfStage;
        let act = run_on_accs(&stage, &accs, frac);
        assert_eq!(act.repr(), Repr::Half);
        for (i, (&a, got)) in accs.iter().zip(&act.half).enumerate() {
            assert_eq!(
                got.0,
                oracle(a, frac).0,
                "acc {a} (case {i}): {} vs oracle {}",
                got.to_f32(),
                oracle(a, frac).to_f32()
            );
        }
    });
}

#[test]
fn sigmoid_clamps_domain_extremes() {
    with_each_kernel(|| {
        let stage = SigmoidLutStage::new(ScalarLut::sigmoid());
        let frac = 8u32;
        // pre-activations: deep negative, zero, deep positive (values
        // -4096, 0, +4096 after scaling — far outside where sigmoid is
        // representably different from its asymptotes)
        let act = run_on_accs(&stage, &[-(1 << 20), 0, 1 << 20], frac);
        assert_eq!(act.repr(), Repr::Half);
        let got: Vec<f32> = act.half.iter().map(|h| h.to_f32()).collect();
        assert_eq!(got, vec![0.0, 0.5, 1.0]);
        // and every f16 the table can produce is finite and in [0,1]
        let probes = [i64::MIN, -(1 << 30), -3, 17, 1 << 30, i64::MAX];
        let act = run_on_accs(&stage, &probes, frac);
        for h in &act.half {
            let v = h.to_f32();
            assert!((0.0..=1.0).contains(&v), "sigmoid out of range: {v}");
        }
    });
}
