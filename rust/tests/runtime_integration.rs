//! PJRT runtime integration: loads the HLO-text artifacts produced by
//! `make artifacts` and verifies that the XLA execution agrees with the
//! in-Rust reference forward on the same weights — the cross-language
//! contract of the whole compile path. Skips (with a notice) when
//! artifacts are absent so `cargo test` works on a fresh clone.

use std::path::Path;
use tablenet::data::synth::Kind;
use tablenet::data::load_or_generate;
use tablenet::nn::{weights, Arch};
use tablenet::runtime::{ref_hlo_path, PjrtModel};
use tablenet::tensor::Tensor;

fn artifacts() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("weights_linear.bin").exists() {
        Some(p)
    } else {
        eprintln!("skipping runtime integration: run `make artifacts` first");
        None
    }
}

#[test]
fn pjrt_linear_matches_rust_reference() {
    let Some(art) = artifacts() else { return };
    let hlo = ref_hlo_path(art, Arch::Linear, 1);
    if !hlo.exists() {
        eprintln!("skipping: {} missing", hlo.display());
        return;
    }
    let model = weights::load_model(Arch::Linear, &art.join("weights_linear.bin")).unwrap();
    let pjrt = PjrtModel::load(&hlo, 1, 784, 10).unwrap();
    let ds = load_or_generate(Path::new("data/synth"), Kind::Digits, 6000, 1000, 7).unwrap();
    let mut max_diff = 0f32;
    for i in 0..16 {
        let img = ds.test.image(i).to_vec();
        let out = pjrt.infer_padded(&[img.clone()]).unwrap();
        let rust_out = model.forward(&Tensor::new(&[1, 784], img));
        for (a, b) in out[0].iter().zip(rust_out.data()) {
            max_diff = max_diff.max((a - b).abs());
        }
    }
    assert!(max_diff < 1e-3, "PJRT vs rust reference diverged: {max_diff}");
}

#[test]
fn pjrt_batch32_matches_batch1() {
    let Some(art) = artifacts() else { return };
    let h1 = ref_hlo_path(art, Arch::Linear, 1);
    let h32 = ref_hlo_path(art, Arch::Linear, 32);
    if !h1.exists() || !h32.exists() {
        eprintln!("skipping: batch artifacts missing");
        return;
    }
    let p1 = PjrtModel::load(&h1, 1, 784, 10).unwrap();
    let p32 = PjrtModel::load(&h32, 32, 784, 10).unwrap();
    let ds = load_or_generate(Path::new("data/synth"), Kind::Digits, 6000, 1000, 7).unwrap();
    let images: Vec<Vec<f32>> = (0..10).map(|i| ds.test.image(i).to_vec()).collect();
    let out32 = p32.infer_padded(&images).unwrap();
    for (i, img) in images.iter().enumerate() {
        let out1 = p1.infer_padded(&[img.clone()]).unwrap();
        for (a, b) in out1[0].iter().zip(&out32[i]) {
            assert!((a - b).abs() < 1e-4, "batch inconsistency at {i}");
        }
    }
}

#[test]
fn pjrt_lut_graph_executes_and_classifies_like_reference() {
    // the Pallas LUT kernel graph (lowered via interpret=True) must be
    // loadable and agree with the reference on argmax
    let Some(art) = artifacts() else { return };
    let hlo = art.join("linear_lut_b1.hlo.txt");
    if !hlo.exists() {
        eprintln!("skipping: {} missing", hlo.display());
        return;
    }
    let model = weights::load_model(Arch::Linear, &art.join("weights_linear.bin")).unwrap();
    let pjrt = PjrtModel::load(&hlo, 1, 784, 10).unwrap();
    let ds = load_or_generate(Path::new("data/synth"), Kind::Digits, 6000, 1000, 7).unwrap();
    let mut agree = 0;
    let n = 24;
    for i in 0..n {
        let img = ds.test.image(i).to_vec();
        let cls = pjrt.classify(&[img.clone()]).unwrap()[0];
        // reference on 3-bit quantized input (the LUT graph quantizes)
        let fmt = tablenet::quant::FixedFormat::new(3);
        let xq: Vec<f32> = img.iter().map(|&v| fmt.fake_quant(v)).collect();
        let rc = model.forward(&Tensor::new(&[1, 784], xq)).argmax_rows()[0];
        if cls == rc {
            agree += 1;
        }
    }
    assert!(agree >= n - 1, "LUT HLO graph agreed on only {agree}/{n}");
}

#[test]
fn pjrt_cnn_loads_when_present() {
    let Some(art) = artifacts() else { return };
    let hlo = ref_hlo_path(art, Arch::Cnn, 1);
    if !hlo.exists() {
        eprintln!("skipping: {} missing", hlo.display());
        return;
    }
    let model = weights::load_model(Arch::Cnn, &art.join("weights_cnn.bin")).unwrap();
    let pjrt = PjrtModel::load(&hlo, 1, 784, 10).unwrap();
    let ds = load_or_generate(Path::new("data/synth"), Kind::Digits, 6000, 1000, 7).unwrap();
    let img = ds.test.image(0).to_vec();
    let out = pjrt.infer_padded(&[img.clone()]).unwrap();
    let rust_out = model.forward(&Tensor::new(&[1, 28, 28, 1], img));
    let mut max_diff = 0f32;
    for (a, b) in out[0].iter().zip(rust_out.data()) {
        max_diff = max_diff.max((a - b).abs());
    }
    assert!(max_diff < 1e-2, "CNN PJRT vs rust reference diverged: {max_diff}");
}
