//! `.ltm` artifact invariants: save -> load -> infer must be bit-exact
//! with the in-memory compiled model across every stage kind the
//! compiler can emit (property-style, over the repo's own PRNG), and
//! corrupted / truncated artifacts must be rejected — never served.

use tablenet::engine::plan::{AffineMode, EnginePlan};
use tablenet::engine::scratch::Scratch;
use tablenet::engine::{artifact, Compiler, LutModel};
use tablenet::nn::Model;
use tablenet::tensor::Tensor;
use tablenet::util::Rng;

fn linear_model(rng: &mut Rng) -> Model {
    Model::linear(
        Tensor::randn(&[10, 784], 0.05, rng),
        Tensor::randn(&[10], 0.02, rng),
    )
}

fn mlp_model(rng: &mut Rng) -> Model {
    Model::mlp(vec![
        (Tensor::randn(&[32, 784], 0.05, rng), Tensor::zeros(&[32])),
        (Tensor::randn(&[16, 32], 0.2, rng), Tensor::zeros(&[16])),
        (Tensor::randn(&[10, 16], 0.3, rng), Tensor::zeros(&[10])),
    ])
}

fn sigmoid_model(rng: &mut Rng) -> Model {
    Model {
        arch: tablenet::nn::Arch::Mlp,
        layers: vec![
            tablenet::nn::Layer::Dense {
                w: Tensor::randn(&[24, 784], 0.05, rng),
                b: Tensor::zeros(&[24]),
            },
            tablenet::nn::Layer::Sigmoid,
            tablenet::nn::Layer::Dense {
                w: Tensor::randn(&[10, 24], 0.3, rng),
                b: Tensor::zeros(&[10]),
            },
        ],
        input_shape: vec![784],
    }
}

fn cnn_model(rng: &mut Rng) -> Model {
    Model {
        arch: tablenet::nn::Arch::Cnn,
        layers: vec![
            tablenet::nn::Layer::Conv2d {
                filter: Tensor::randn(&[3, 3, 1, 2], 0.3, rng),
                b: Tensor::randn(&[2], 0.05, rng),
            },
            tablenet::nn::Layer::Relu,
            tablenet::nn::Layer::MaxPool2,
            tablenet::nn::Layer::Conv2d {
                filter: Tensor::randn(&[3, 3, 2, 3], 0.2, rng),
                b: Tensor::randn(&[3], 0.05, rng),
            },
            tablenet::nn::Layer::Relu,
            tablenet::nn::Layer::Flatten,
            tablenet::nn::Layer::Dense {
                w: Tensor::randn(&[10, 4 * 4 * 3], 0.2, rng),
                b: Tensor::zeros(&[10]),
            },
        ],
        input_shape: vec![8, 8, 1],
    }
}

/// Every (model, plan) the compiler handles: linear bitplane, MLP with
/// whole-fixed input + float inner, MLP with fixed inner (ToFixed),
/// sigmoid (scalar LUT), CNN (both conv banks, maxpool, relu).
fn cases(rng: &mut Rng) -> Vec<(Model, EnginePlan)> {
    let float11 = AffineMode::Float { planes: 11, m: 1 };
    vec![
        (linear_model(rng), EnginePlan::linear_default()),
        (linear_model(rng), EnginePlan::linear_parity()),
        (mlp_model(rng), EnginePlan::mlp_fixed_input()),
        (
            mlp_model(rng),
            EnginePlan {
                affine: vec![
                    AffineMode::WholeFixed { bits: 8, m: 1, range_exp: 0 },
                    AffineMode::BitplaneFixed { bits: 8, m: 4, range_exp: 3 },
                    AffineMode::BitplaneFixed { bits: 8, m: 4, range_exp: 3 },
                ],
                fallback: float11,
                r_o: 16,
            },
        ),
        (
            sigmoid_model(rng),
            EnginePlan { affine: vec![float11, float11], fallback: float11, r_o: 16 },
        ),
        (
            cnn_model(rng),
            EnginePlan {
                affine: vec![
                    AffineMode::BitplaneFixed { bits: 3, m: 2, range_exp: 0 },
                    float11,
                    float11,
                ],
                fallback: float11,
                r_o: 16,
            },
        ),
    ]
}

#[test]
fn prop_save_load_infer_batch_bit_exact() {
    let mut rng = Rng::new(0xA27F);
    for (case, (model, plan)) in cases(&mut rng).into_iter().enumerate() {
        let lut = Compiler::new(&model).plan(&plan).build().unwrap();
        let bytes = artifact::to_bytes(&lut);
        let back = artifact::from_bytes(&bytes).unwrap();
        assert_eq!(back.plan(), lut.plan(), "case {case}: plan diverged");
        assert_eq!(back.size_bits(), lut.size_bits(), "case {case}: size diverged");
        assert_eq!(back.num_stages(), lut.num_stages(), "case {case}");
        for (a, b) in lut.stages().iter().zip(back.stages()) {
            assert_eq!(a.kind(), b.kind(), "case {case}: stage kinds diverged");
        }

        let features: usize = model.input_shape.iter().product();
        let batch = 3;
        let images: Vec<f32> = (0..batch * features).map(|_| rng.f32()).collect();
        let mut s1 = Scratch::new();
        let mut s2 = Scratch::new();
        let got = lut.infer_batch(&images, batch, &mut s1);
        let loaded = back.infer_batch(&images, batch, &mut s2);
        assert_eq!(got.classes, loaded.classes, "case {case}: classes diverged");
        assert_eq!(got.logits, loaded.logits, "case {case}: logits diverged");
        assert_eq!(got.counters, loaded.counters, "case {case}: counters diverged");
        assert_eq!(
            got.per_sample, loaded.per_sample,
            "case {case}: per-sample counters diverged"
        );
        loaded.counters.assert_multiplier_less();
    }
}

#[test]
fn file_roundtrip_through_save_and_load() {
    let mut rng = Rng::new(0xF11E);
    let model = linear_model(&mut rng);
    let lut = Compiler::new(&model).build().unwrap();
    let dir = std::env::temp_dir().join("tablenet_test_artifact");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("linear.ltm");
    lut.save(&path).unwrap();
    let back = LutModel::load(&path).unwrap();
    let x: Vec<f32> = (0..784).map(|_| rng.f32()).collect();
    let a = lut.infer(&x);
    let b = back.infer(&x);
    assert_eq!(a.class, b.class);
    assert_eq!(a.logits, b.logits);
    assert_eq!(a.counters, b.counters);
    std::fs::remove_file(&path).ok();
}

/// v1 -> v2 compatibility matrix over every stage kind the compiler
/// emits: the same loader entry point must serve BOTH container
/// versions bit-exactly — v2 borrowing its arenas zero-copy from the
/// mapping, v1 copying onto the heap — and the two loads must agree
/// with the in-memory compiled model and with each other.
#[test]
fn prop_v1_v2_compatibility_matrix() {
    let mut rng = Rng::new(0x51AB);
    let dir = std::env::temp_dir().join("tablenet_compat_matrix");
    std::fs::create_dir_all(&dir).unwrap();
    for (case, (model, plan)) in cases(&mut rng).into_iter().enumerate() {
        let lut = Compiler::new(&model).plan(&plan).build().unwrap();
        let p_v2 = dir.join(format!("case{case}_v2.ltm"));
        let p_v1 = dir.join(format!("case{case}_v1.ltm"));
        lut.save(&p_v2).unwrap();
        std::fs::write(&p_v1, artifact::to_bytes_v1(&lut)).unwrap();

        let v2 = LutModel::load(&p_v2).unwrap();
        let v1 = LutModel::load(&p_v1).unwrap();

        // residency: v1 owns everything; mapped v2 borrows every arena
        let s1 = v1.storage_summary();
        assert_eq!(s1.borrowed, 0, "case {case}: v1 must load via the copy path");
        #[cfg(unix)]
        {
            let s2 = v2.storage_summary();
            assert_eq!(
                s2.borrowed, s2.banks,
                "case {case}: mapped v2 arenas must be borrowed ({s2:?})"
            );
        }

        // inspect agrees on the version split and checksum presence
        let i2 = artifact::inspect(&p_v2).unwrap();
        let i1 = artifact::inspect(&p_v1).unwrap();
        assert_eq!((i2.version, i1.version), (2, 1), "case {case}");
        assert!(i2.stages.iter().all(|s| s.checksum.is_some()), "case {case}");
        assert!(i1.stages.iter().all(|s| s.checksum.is_none()), "case {case}");

        // bit-exact three ways: in-memory vs v2-mapped vs v1-copied
        let features: usize = model.input_shape.iter().product();
        let batch = 3;
        let images: Vec<f32> = (0..batch * features).map(|_| rng.f32()).collect();
        let mut s = Scratch::new();
        let want = lut.infer_batch(&images, batch, &mut s);
        for (tag, loaded) in [("v2", &v2), ("v1", &v1)] {
            let mut s = Scratch::new();
            let got = loaded.infer_batch(&images, batch, &mut s);
            assert_eq!(got.classes, want.classes, "case {case} {tag}: classes");
            assert_eq!(got.logits, want.logits, "case {case} {tag}: logits");
            assert_eq!(got.per_sample, want.per_sample, "case {case} {tag}: counters");
            got.counters.assert_multiplier_less();
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// v2 per-stage checksums LOCALISE corruption: a flipped byte inside a
/// stage payload is reported with that stage's index, kind and file
/// offset; truncation inside the payload region names the stage whose
/// record no longer fits.
#[test]
fn v2_corruption_is_rejected_with_stage_and_offset() {
    let mut rng = Rng::new(0x10CA);
    let model = mlp_model(&mut rng);
    let lut = Compiler::new(&model).plan(&EnginePlan::mlp_fixed_input()).build().unwrap();
    let bytes = artifact::to_bytes(&lut);
    let info = artifact::inspect_bytes(&bytes).unwrap();
    assert!(info.stages.len() >= 3, "want a multi-stage pipeline");

    // flip one byte in the middle of EVERY stage payload in turn: the
    // error must name that stage and its offset
    for (i, st) in info.stages.iter().enumerate() {
        if st.payload_bytes == 0 {
            continue;
        }
        let mut bad = bytes.clone();
        bad[(st.offset + st.payload_bytes / 2) as usize] ^= 0x04;
        let err = format!("{:#}", artifact::from_bytes(&bad).unwrap_err());
        assert!(err.contains("checksum mismatch"), "stage {i}: {err}");
        assert!(err.contains(&format!("stage {i}")), "stage {i}: {err}");
        assert!(err.contains(&format!("{:#x}", st.offset)), "stage {i}: {err}");
    }

    // truncation inside the payload region names the first stage whose
    // payload no longer fits
    let last = info.stages.last().unwrap();
    let cut = (last.offset + last.payload_bytes / 2) as usize;
    let err = format!("{:#}", artifact::from_bytes(&bytes[..cut]).unwrap_err());
    let i = info.stages.len() - 1;
    assert!(
        err.contains(&format!("stage {i}")) && err.contains("truncated"),
        "truncation error must name stage {i}: {err}"
    );
}

#[test]
fn prop_corrupted_artifacts_are_rejected() {
    let mut rng = Rng::new(0xBADF);
    let model = linear_model(&mut rng);
    let plan = EnginePlan {
        affine: vec![AffineMode::BitplaneFixed { bits: 3, m: 8, range_exp: 0 }],
        fallback: AffineMode::Float { planes: 11, m: 1 },
        r_o: 16,
    };
    let lut = Compiler::new(&model).plan(&plan).build().unwrap();
    let bytes = artifact::to_bytes(&lut);

    // pristine bytes parse
    assert!(artifact::from_bytes(&bytes).is_ok());

    // any single flipped bit is caught (checksum), wherever it lands
    for _ in 0..50 {
        let mut mutated = bytes.clone();
        let i = rng.below(mutated.len());
        let bit = 1u8 << (rng.below(8) as u8);
        mutated[i] ^= bit;
        assert!(
            artifact::from_bytes(&mutated).is_err(),
            "flipped bit {bit:#x} at byte {i}/{} was accepted",
            mutated.len()
        );
    }

    // every truncation point is rejected
    for cut in [1, 8, 100, bytes.len() / 2, bytes.len() - 1] {
        assert!(
            artifact::from_bytes(&bytes[..cut]).is_err(),
            "truncation at {cut} was accepted"
        );
    }

    // wrong magic / version with an otherwise plausible prefix
    let mut wrong_magic = bytes.clone();
    wrong_magic[0] = b'X';
    assert!(artifact::from_bytes(&wrong_magic).is_err());
}
