//! Steady-state allocation discipline of the batched engine: after one
//! warm-up batch, `LutModel::infer_batch_into` must perform ZERO heap
//! allocations — every intermediate lives in the reusable `Scratch`
//! arena and the output struct's buffers are recycled.
//!
//! Enforced for real with a counting global allocator: this test file
//! is its own crate, so the `#[global_allocator]` below only governs
//! this binary. Exactly one test lives here — libtest runs it on a
//! single thread, so the counter observes only the code under test.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use tablenet::engine::plan::{AffineMode, EnginePlan};
use tablenet::engine::scratch::Scratch;
use tablenet::engine::{BatchInference, Compiler};
use tablenet::nn::Model;
use tablenet::tensor::Tensor;
use tablenet::util::Rng;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_infer_batch_allocates_nothing() {
    // linear bitplane pipeline (quantize -> bitplane bank -> argmax):
    // m=8 keeps the arena small while exercising the packed-plane path
    let mut rng = Rng::new(0xA110C);
    let (p, q) = (10usize, 784usize);
    let model = Model::linear(
        Tensor::randn(&[p, q], 0.05, &mut rng),
        Tensor::randn(&[p], 0.02, &mut rng),
    );
    let plan = EnginePlan {
        affine: vec![AffineMode::BitplaneFixed { bits: 3, m: 8, range_exp: 0 }],
        fallback: AffineMode::Float { planes: 11, m: 1 },
        r_o: 16,
    };
    let lut = Compiler::new(&model).plan(&plan).build().unwrap();

    let batch = 16usize;
    let images: Vec<f32> = (0..batch * q).map(|_| rng.f32()).collect();
    let mut scratch = Scratch::new();
    let mut out = BatchInference::default();

    // warm-up: buffers reach their high-water capacity
    lut.infer_batch_into(&images, batch, &mut scratch, &mut out);
    lut.infer_batch_into(&images, batch, &mut scratch, &mut out);
    out.counters.assert_multiplier_less();
    let before = ALLOC_CALLS.load(Ordering::SeqCst);

    for _ in 0..10 {
        lut.infer_batch_into(&images, batch, &mut scratch, &mut out);
    }

    let after = ALLOC_CALLS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "steady-state infer_batch performed {} heap allocations",
        after - before
    );

    // sanity: the warmed path still produces correct, multiplier-less
    // results (compare one sample against the per-sample engine —
    // AFTER the measured window, since infer() allocates by design)
    out.counters.assert_multiplier_less();
    let single = lut.infer(&images[..q]);
    assert_eq!(out.classes[0], single.class);
}
