//! Property-based invariant tests. The vendored crate set has no
//! proptest, so this file carries a small deterministic forall-runner
//! over the repo's own PRNG: each property is checked across a few
//! hundred random cases with seeds printed on failure.

use tablenet::config::json::Json;
use tablenet::config::{plan_from_json, plan_to_json};
use tablenet::engine::counters::Counters;
use tablenet::engine::plan::{AffineMode, EnginePlan};
use tablenet::lut::bitplane::DenseBitplaneLut;
use tablenet::lut::cost::{dense_cost, IndexMode};
use tablenet::lut::dense::DenseWholeLut;
use tablenet::lut::{from_acc, Partition};
use tablenet::quant::f16::F16;
use tablenet::quant::stochastic::StochasticRounder;
use tablenet::quant::FixedFormat;
use tablenet::util::Rng;

/// forall-runner: `cases` seeds, prints the failing seed.
fn forall(name: &str, cases: u64, mut prop: impl FnMut(&mut Rng)) {
    for seed in 0..cases {
        let mut rng = Rng::new(0x5EED_0000 + seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng)
        }));
        if let Err(e) = result {
            panic!("property '{name}' failed at seed {seed}: {e:?}");
        }
    }
}

fn rand_affine(rng: &mut Rng, p: usize, q: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    (
        (0..p * q).map(|_| rng.normal() * 0.5).collect(),
        (0..p).map(|_| rng.normal() * 0.1).collect(),
        (0..q).map(|_| rng.f32()).collect(),
    )
}

fn ref_affine(w: &[f32], b: &[f32], p: usize, q: usize, x: &[f32]) -> Vec<f32> {
    (0..p)
        .map(|o| b[o] + (0..q).map(|i| w[o * q + i] * x[i]).sum::<f32>())
        .collect()
}

#[test]
fn prop_random_partitions_cover_exactly_once() {
    forall("partition-cover", 300, |rng| {
        let q = 1 + rng.below(64);
        let m = 1 + rng.below(q);
        let p = Partition::contiguous(q, m);
        p.validate().unwrap();
        let total: usize = p.chunks.iter().map(Vec::len).sum();
        assert_eq!(total, q);
        assert!(p.max_chunk() <= m);
    });
}

#[test]
fn prop_lut_equals_reference_on_quantized_input() {
    forall("lut-vs-ref", 120, |rng| {
        let p = 1 + rng.below(8);
        let q = 2 + rng.below(20);
        let m = 1 + rng.below(6.min(q));
        let bits = 1 + rng.below(6) as u32;
        let (w, b, x) = rand_affine(rng, p, q);
        let fmt = FixedFormat::new(bits);
        let lut =
            DenseBitplaneLut::build(&w, &b, p, q, Partition::contiguous(q, m), fmt)
                .unwrap();
        let mut ctr = Counters::default();
        let acc = lut.eval_f32(&x, &mut ctr);
        ctr.assert_multiplier_less();
        let xq: Vec<f32> = x.iter().map(|&v| fmt.fake_quant(v)).collect();
        let want = ref_affine(&w, &b, p, q, &xq);
        for (o, &a) in acc.iter().enumerate() {
            assert!(
                (from_acc(a, 0) - want[o]).abs() < 1e-3,
                "p={p} q={q} m={m} bits={bits}: {} vs {}",
                from_acc(a, 0),
                want[o]
            );
        }
    });
}

#[test]
fn prop_whole_and_bitplane_banks_agree() {
    forall("whole-vs-bitplane", 80, |rng| {
        let p = 1 + rng.below(6);
        let q = 2 + rng.below(12);
        let m = 1 + rng.below(3.min(q));
        let bits = 1 + rng.below(4) as u32;
        let (w, b, x) = rand_affine(rng, p, q);
        let fmt = FixedFormat::new(bits);
        let whole =
            DenseWholeLut::build(&w, &b, p, q, Partition::contiguous(q, m), fmt).unwrap();
        let plane =
            DenseBitplaneLut::build(&w, &b, p, q, Partition::contiguous(q, m), fmt)
                .unwrap();
        let mut c1 = Counters::default();
        let mut c2 = Counters::default();
        let a1 = whole.eval_f32(&x, &mut c1);
        let a2 = plane.eval_f32(&x, &mut c2);
        for (u, v) in a1.iter().zip(&a2) {
            assert!((from_acc(*u, 0) - from_acc(*v, 0)).abs() < 1e-4);
        }
    });
}

#[test]
fn prop_engine_eval_counts_match_cost_model() {
    // the measured lut_evals of a bitplane bank == the planner's n·k
    forall("counters-vs-cost", 60, |rng| {
        let p = 1 + rng.below(6);
        let q = 2 + rng.below(20);
        let m = 1 + rng.below(5.min(q));
        let bits = 1 + rng.below(5) as u32;
        let (w, b, x) = rand_affine(rng, p, q);
        let lut = DenseBitplaneLut::build(
            &w, &b, p, q, Partition::contiguous(q, m), FixedFormat::new(bits),
        )
        .unwrap();
        let mut ctr = Counters::default();
        let _ = lut.eval_f32(&x, &mut ctr);
        let cost = dense_cost(
            q as u64, p as u64, m as u64, IndexMode::BitplaneFixed { r_i: bits }, 16,
        );
        assert_eq!(ctr.lut_evals, cost.lut_evals);
        // measured adds never exceed the model's inclusive bound
        assert!(ctr.shift_adds <= cost.adds_inclusive);
    });
}

#[test]
fn prop_eval_batch_bit_exact_with_per_sample() {
    // the batched, arena-backed path must agree BIT-EXACTLY with the
    // per-sample path across random partitions, bit-widths and batch
    // sizes — and stay multiplier-less
    forall("eval-batch-vs-single", 80, |rng| {
        let p = 1 + rng.below(8);
        let q = 2 + rng.below(24);
        let m = 1 + rng.below(8.min(q));
        let bits = 1 + rng.below(9) as u32; // crosses the packed-path gate
        let batch = 1 + rng.below(8);
        let fmt = FixedFormat::new(bits);
        let (w, b, _) = rand_affine(rng, p, q);
        let codes: Vec<u32> = (0..batch * q)
            .map(|_| rng.below(fmt.levels() as usize) as u32)
            .collect();

        let plane =
            DenseBitplaneLut::build(&w, &b, p, q, Partition::contiguous(q, m), fmt)
                .unwrap();
        let mut out = vec![0i64; batch * p];
        let mut cb = vec![Counters::default(); batch];
        plane.eval_batch(&codes, batch, &mut out, &mut cb);
        for s in 0..batch {
            cb[s].assert_multiplier_less();
            let mut cs = Counters::default();
            let single = plane.eval_codes(&codes[s * q..(s + 1) * q], &mut cs);
            assert_eq!(
                &out[s * p..(s + 1) * p],
                single.as_slice(),
                "bitplane p={p} q={q} m={m} bits={bits} batch={batch} sample={s}"
            );
            assert_eq!(
                cb[s], cs,
                "bitplane per-sample counters p={p} q={q} m={m} bits={bits} sample={s}"
            );
        }

        // whole-code bank (small m·bits only: table is 2^(m·bits) rows)
        if m as u32 * bits < 12 {
            let whole =
                DenseWholeLut::build(&w, &b, p, q, Partition::contiguous(q, m), fmt)
                    .unwrap();
            let mut wout = vec![0i64; batch * p];
            let mut wb = vec![Counters::default(); batch];
            whole.eval_batch(&codes, batch, &mut wout, &mut wb);
            for s in 0..batch {
                wb[s].assert_multiplier_less();
                let mut ws = Counters::default();
                let single = whole.eval_codes(&codes[s * q..(s + 1) * q], &mut ws);
                assert_eq!(
                    &wout[s * p..(s + 1) * p],
                    single.as_slice(),
                    "whole p={p} q={q} m={m} bits={bits} sample={s}"
                );
                assert_eq!(wb[s], ws);
            }
        }
    });
}

#[test]
fn prop_float_eval_batch_bit_exact_with_per_sample() {
    use tablenet::lut::floatplane::{DenseFloatLut, FloatLutConfig};
    forall("float-batch-vs-single", 40, |rng| {
        let p = 1 + rng.below(6);
        let q = 2 + rng.below(10);
        let m = 1 + rng.below(3.min(q));
        let batch = 1 + rng.below(6);
        let (w, b, _) = rand_affine(rng, p, q);
        let lut = DenseFloatLut::build(
            &w, &b, p, q, Partition::contiguous(q, m), FloatLutConfig::default(),
        )
        .unwrap();
        let x: Vec<F16> = (0..batch * q)
            .map(|_| F16::from_f32(rng.f32() * 8.0))
            .collect();
        let mut out = vec![0i64; batch * p];
        let mut cb = vec![Counters::default(); batch];
        lut.eval_batch_f16(&x, batch, &mut out, &mut cb);
        for s in 0..batch {
            cb[s].assert_multiplier_less();
            let mut cs = Counters::default();
            let single = lut.eval_f16(&x[s * q..(s + 1) * q], &mut cs);
            assert_eq!(
                &out[s * p..(s + 1) * p],
                single.as_slice(),
                "float p={p} q={q} m={m} batch={batch} sample={s}"
            );
            assert_eq!(cb[s], cs, "float per-sample counters sample={s}");
        }
    });
}

#[test]
fn prop_kernel_parity_dense_banks() {
    // forced scalar vs forced avx2 must agree BIT-EXACTLY — outputs AND
    // per-sample counters — across random partitions, bit-widths, plane
    // counts and ragged batch sizes (1..=9 straddles the 4-lane width)
    use tablenet::lut::floatplane::{DenseFloatLut, FloatLutConfig};
    use tablenet::lut::kernel;
    if !kernel::avx2_available() {
        eprintln!("skipping kernel-parity property: host CPU lacks AVX2");
        return;
    }
    forall("kernel-parity-dense", 60, |rng| {
        let p = 1 + rng.below(8);
        let q = 2 + rng.below(24);
        let m = 1 + rng.below(8.min(q));
        let bits = 1 + rng.below(9) as u32; // crosses the packed-path gate
        let batch = 1 + rng.below(9);
        let fmt = FixedFormat::new(bits);
        let (w, b, _) = rand_affine(rng, p, q);
        let codes: Vec<u32> = (0..batch * q)
            .map(|_| rng.below(fmt.levels() as usize) as u32)
            .collect();

        let plane =
            DenseBitplaneLut::build(&w, &b, p, q, Partition::contiguous(q, m), fmt)
                .unwrap();
        let run = |k: kernel::Kernel| {
            let _g = kernel::force(k);
            let mut out = vec![0i64; batch * p];
            let mut ctrs = vec![Counters::default(); batch];
            plane.eval_batch(&codes, batch, &mut out, &mut ctrs);
            (out, ctrs)
        };
        let (o_s, c_s) = run(kernel::Kernel::Scalar);
        let (o_v, c_v) = run(kernel::Kernel::Avx2);
        assert_eq!(o_s, o_v, "bitplane p={p} q={q} m={m} bits={bits} batch={batch}");
        assert_eq!(c_s, c_v, "bitplane counters p={p} q={q} m={m} bits={bits}");

        // whole-code bank (small m·bits only: table is 2^(m·bits) rows)
        if m as u32 * bits < 12 {
            let whole =
                DenseWholeLut::build(&w, &b, p, q, Partition::contiguous(q, m), fmt)
                    .unwrap();
            let run = |k: kernel::Kernel| {
                let _g = kernel::force(k);
                let mut out = vec![0i64; batch * p];
                let mut ctrs = vec![Counters::default(); batch];
                whole.eval_batch(&codes, batch, &mut out, &mut ctrs);
                (out, ctrs)
            };
            let (o_s, c_s) = run(kernel::Kernel::Scalar);
            let (o_v, c_v) = run(kernel::Kernel::Avx2);
            assert_eq!(o_s, o_v, "whole p={p} q={q} m={m} bits={bits} batch={batch}");
            assert_eq!(c_s, c_v, "whole counters p={p} q={q} m={m} bits={bits}");
        }

        // binary16 mantissa-plane bank (m ≤ 2 keeps the 2^(6m)-row
        // build cheap across many cases; m=3 has a dedicated unit test)
        let fm = 1 + rng.below(2.min(q));
        let planes = 1 + rng.below(11) as u32;
        let flut = DenseFloatLut::build(
            &w, &b, p, q, Partition::contiguous(q, fm), FloatLutConfig { planes },
        )
        .unwrap();
        let xs: Vec<F16> = (0..batch * q)
            .map(|_| F16::from_f32(rng.f32() * 8.0))
            .collect();
        let run = |k: kernel::Kernel| {
            let _g = kernel::force(k);
            let mut out = vec![0i64; batch * p];
            let mut ctrs = vec![Counters::default(); batch];
            flut.eval_batch_f16(&xs, batch, &mut out, &mut ctrs);
            (out, ctrs)
        };
        let (o_s, c_s) = run(kernel::Kernel::Scalar);
        let (o_v, c_v) = run(kernel::Kernel::Avx2);
        assert_eq!(o_s, o_v, "float p={p} q={q} m={fm} planes={planes} batch={batch}");
        assert_eq!(c_s, c_v, "float counters p={p} q={q} m={fm} planes={planes}");
    });
}

#[test]
fn prop_kernel_parity_conv_banks() {
    // same guarantee for the conv banks: forced scalar vs forced avx2,
    // bit-exact outputs and per-sample counters over random geometries
    use tablenet::lut::conv::ConvLut;
    use tablenet::lut::convfloat::ConvFloatLut;
    use tablenet::lut::kernel;
    if !kernel::avx2_available() {
        eprintln!("skipping kernel-parity property: host CPU lacks AVX2");
        return;
    }
    forall("kernel-parity-conv", 24, |rng| {
        let m = 1 + rng.below(2);
        let h = m * (1 + rng.below(3));
        let w = m * (1 + rng.below(3));
        let cin = 1 + rng.below(3);
        let cout = 1 + rng.below(3);
        let r = 1;
        let bits = 1 + rng.below(3) as u32;
        let batch = 1 + rng.below(5);
        let fs = 2 * r + 1;
        let filter: Vec<f32> = (0..fs * fs * cin * cout)
            .map(|_| rng.normal() * 0.3)
            .collect();
        let bias: Vec<f32> = (0..cout).map(|_| rng.normal() * 0.1).collect();
        let fmt = FixedFormat::new(bits);

        let conv = ConvLut::build(&filter, &bias, h, w, cin, cout, r, m, fmt).unwrap();
        let codes: Vec<u32> = (0..batch * h * w * cin)
            .map(|_| rng.below(fmt.levels() as usize) as u32)
            .collect();
        let run = |k: kernel::Kernel| {
            let _g = kernel::force(k);
            let mut out = vec![0i64; batch * h * w * cout];
            let mut pad = Vec::new();
            let mut ctrs = vec![Counters::default(); batch];
            conv.eval_batch(&codes, batch, &mut out, &mut pad, &mut ctrs);
            (out, ctrs)
        };
        let (o_s, c_s) = run(kernel::Kernel::Scalar);
        let (o_v, c_v) = run(kernel::Kernel::Avx2);
        assert_eq!(o_s, o_v, "conv h={h} w={w} cin={cin} cout={cout} m={m} bits={bits}");
        assert_eq!(c_s, c_v, "conv counters h={h} w={w} m={m} bits={bits}");

        let planes = 1 + rng.below(11) as u32;
        let cf = ConvFloatLut::build(&filter, &bias, h, w, cin, cout, r, planes).unwrap();
        let xs: Vec<F16> = (0..batch * h * w * cin)
            .map(|_| F16::from_f32(rng.f32() * 4.0))
            .collect();
        let run = |k: kernel::Kernel| {
            let _g = kernel::force(k);
            let mut out = vec![0i64; batch * h * w * cout];
            let mut pad = Vec::new();
            let mut ctrs = vec![Counters::default(); batch];
            cf.eval_batch_f16(&xs, batch, &mut out, &mut pad, &mut ctrs);
            (out, ctrs)
        };
        let (o_s, c_s) = run(kernel::Kernel::Scalar);
        let (o_v, c_v) = run(kernel::Kernel::Avx2);
        assert_eq!(o_s, o_v, "convfloat h={h} w={w} cin={cin} planes={planes}");
        assert_eq!(c_s, c_v, "convfloat counters h={h} w={w} planes={planes}");
    });
}

#[test]
fn prop_engine_infer_batch_matches_per_sample() {
    // whole-pipeline parity: classes, logits and counter TOTALS of
    // infer_batch equal the per-sample infer results, and the batched
    // path records zero multiplies
    use tablenet::engine::scratch::Scratch;
    use tablenet::engine::Compiler;
    use tablenet::nn::Model;
    use tablenet::tensor::Tensor;
    forall("engine-batch-vs-single", 8, |rng| {
        let q = 32 + rng.below(64);
        let p = 4 + rng.below(8);
        let model = Model::linear(
            Tensor::randn(&[p, q], 0.1, rng),
            Tensor::randn(&[p], 0.05, rng),
        );
        let m = 1 + rng.below(8);
        let bits = 1 + rng.below(4) as u32;
        let plan = EnginePlan {
            affine: vec![AffineMode::BitplaneFixed { bits, m, range_exp: 0 }],
            fallback: AffineMode::Float { planes: 11, m: 1 },
            r_o: 16,
        };
        let lut = Compiler::new(&model).plan(&plan).build().unwrap();
        let batch = 1 + rng.below(6);
        let images: Vec<f32> = (0..batch * q).map(|_| rng.f32()).collect();
        let mut scratch = Scratch::new();
        let got = lut.infer_batch(&images, batch, &mut scratch);
        assert_eq!(got.counters.mults, 0);
        let mut total = Counters::default();
        for s in 0..batch {
            let single = lut.infer(&images[s * q..(s + 1) * q]);
            assert_eq!(got.classes[s], single.class);
            assert_eq!(got.logits_row(s), single.logits.as_slice());
            total += single.counters;
        }
        assert_eq!(got.counters, total);
    });
}

#[test]
fn prop_hot_swap_exactly_once_version_attributed() {
    // Hot-swap under sustained concurrent load, across random batching
    // policies: every submitted request gets exactly ONE response, each
    // response is attributable to exactly one model version (the
    // backend stamps its version into `class`, and the coordinator
    // reports the version the batch executed on — they must agree, so
    // no batch can mix versions), and once the pipeline quiesces after
    // the final swap, responses come from the final version.
    use std::sync::Arc;
    use tablenet::config::ServeConfig;
    use tablenet::coordinator::{Backend, Coordinator, InferOutput};

    /// Version-stamped echo: class == the version this backend was
    /// installed as.
    struct VersionEcho(usize);

    impl Backend for VersionEcho {
        fn infer_batch(&self, images: &[Vec<f32>]) -> Vec<InferOutput> {
            images
                .iter()
                .map(|_| InferOutput {
                    class: self.0,
                    logits: vec![self.0 as f32],
                    counters: Counters { lut_evals: 1, ..Default::default() },
                })
                .collect()
        }

        fn name(&self) -> &'static str {
            "version-echo"
        }
    }

    forall("hot-swap-exactly-once", 6, |rng| {
        let cfg = ServeConfig {
            max_batch: 1 + rng.below(16),
            max_wait_us: 50 + rng.below(300) as u64,
            workers: 1 + rng.below(3),
            queue_cap: 256,
            ..ServeConfig::default()
        };
        let n_threads = 3usize;
        let per_thread = 50usize;
        let n_swaps = 1 + rng.below(3);
        let coord = Coordinator::start(Arc::new(VersionEcho(1)), &cfg);
        let mut joins = Vec::new();
        for _ in 0..n_threads {
            let client = coord.client();
            joins.push(std::thread::spawn(move || {
                let mut seen = Vec::with_capacity(per_thread);
                for _ in 0..per_thread {
                    let r = client.infer_blocking(vec![0.5]).unwrap();
                    seen.push((r.class, r.version, r.logits[0]));
                }
                seen
            }));
        }
        for v in 0..n_swaps {
            std::thread::sleep(std::time::Duration::from_micros(500));
            let installed = coord.swap(Arc::new(VersionEcho(2 + v)));
            assert_eq!(installed as usize, 2 + v);
        }
        let final_version = (1 + n_swaps) as u64;
        let mut responses = Vec::new();
        for j in joins {
            responses.extend(j.join().unwrap());
        }
        // exactly one response per submitted request
        assert_eq!(responses.len(), n_threads * per_thread);
        for (class, version, logit0) in &responses {
            // exact version attribution: the stamped payload agrees
            // with the version the coordinator says served the batch
            assert_eq!(*class as u64, *version, "response attributed to wrong version");
            assert_eq!(*logit0, *class as f32);
            assert!(
                (1..=final_version).contains(version),
                "impossible version {version}"
            );
        }
        // quiesced pipeline: post-swap requests run the final version
        let client = coord.client();
        let r = client.infer_blocking(vec![0.5]).unwrap();
        assert_eq!(r.version, final_version, "post-swap response from stale version");
        assert_eq!(r.class as u64, final_version);
        let snap = coord.shutdown();
        assert_eq!(snap.completed as usize, n_threads * per_thread + 1);
        assert_eq!(snap.swaps as usize, n_swaps);
        assert_eq!(snap.ops.lut_evals as usize, n_threads * per_thread + 1);
    });
}

#[test]
fn prop_fleet_chaos_exactly_one_verdict_with_valid_versions() {
    // Concurrent register / quarantined-swap / retire / infer under an
    // injected FaultPlan, across random batching policies and fault
    // rates: every request gets exactly ONE verdict (response or typed
    // error), every response's stamped payload agrees with the version
    // the coordinator attributes it to, and versions never go
    // backwards on a pipeline's lifetime (a retired-then-re-registered
    // model is a NEW pipeline and exempt).
    use std::sync::Arc;
    use tablenet::config::ServeConfig;
    use tablenet::coordinator::faults::{
        silence_injected_panics, FaultInjector, FaultPlan, InjectedPanic,
    };
    use tablenet::coordinator::registry::ModelRegistry;
    use tablenet::coordinator::router::RouteError;
    use tablenet::coordinator::{Backend, InferOutput, ServeError};

    /// Version-stamped echo: class == the version this backend is
    /// installed as.
    struct VersionEcho(usize);

    impl Backend for VersionEcho {
        fn infer_batch(&self, images: &[Vec<f32>]) -> Vec<InferOutput> {
            images
                .iter()
                .map(|_| InferOutput {
                    class: self.0,
                    logits: vec![self.0 as f32],
                    counters: Counters { lut_evals: 1, ..Default::default() },
                })
                .collect()
        }

        fn input_features(&self) -> Option<usize> {
            Some(1)
        }

        fn name(&self) -> &'static str {
            "version-echo"
        }
    }

    /// Broken candidate: must never survive swap quarantine.
    struct Exploding;

    impl Backend for Exploding {
        fn infer_batch(&self, _images: &[Vec<f32>]) -> Vec<InferOutput> {
            std::panic::panic_any(InjectedPanic)
        }

        fn input_features(&self) -> Option<usize> {
            Some(1)
        }

        fn name(&self) -> &'static str {
            "exploding"
        }
    }

    silence_injected_panics();
    forall("fleet-chaos-exactly-once", 5, |rng| {
        let plan = FaultPlan {
            seed: rng.next_u64(),
            latency_prob: (rng.f32() * 0.2) as f64,
            latency_us: 200 + rng.below(400) as u64,
            panic_prob: (rng.f32() * 0.1) as f64,
        };
        let reg = ModelRegistry::with_faults(Arc::new(FaultInjector::new(plan)));
        let cfg = ServeConfig {
            max_batch: 1 + rng.below(8),
            max_wait_us: 50 + rng.below(200) as u64,
            workers: 1 + rng.below(2),
            queue_cap: 64,
            deadline_us: 0,
            degrade_after: 3,
            ..ServeConfig::default()
        };
        reg.register("stable", Arc::new(VersionEcho(1)), &cfg).unwrap();
        reg.register("churn", Arc::new(VersionEcho(1)), &cfg).unwrap();
        reg.register("ephemeral", Arc::new(VersionEcho(1)), &cfg).unwrap();

        let n_threads = 3usize;
        let per_thread = 60usize;
        let mut joins = Vec::new();
        for t in 0..n_threads {
            let client = reg.client();
            joins.push(std::thread::spawn(move || {
                let mut verdicts = 0usize;
                // per-model high-water versions; index 2 ("ephemeral")
                // is retired/re-registered mid-run so only the first
                // two assert monotonicity
                let mut last = [0u64; 3];
                for i in 0..per_thread {
                    let m = (t + i) % 3;
                    let name = ["stable", "churn", "ephemeral"][m];
                    match client.infer(name, vec![0.5]) {
                        Ok(r) => {
                            verdicts += 1;
                            assert_eq!(
                                r.class as u64, r.version,
                                "'{name}': payload disagrees with attributed version"
                            );
                            if m < 2 {
                                assert!(
                                    r.version >= last[m],
                                    "'{name}' version went backwards: {} after {}",
                                    r.version,
                                    last[m]
                                );
                            }
                            last[m] = r.version;
                        }
                        Err(RouteError::Submit(
                            ServeError::WorkerPanicked
                            | ServeError::QueueFull
                            | ServeError::DeadlineExceeded { .. },
                        )) => verdicts += 1,
                        // retired mid-run: a typed routing error, not a hang
                        Err(RouteError::UnknownModel(_)) => verdicts += 1,
                        Err(other) => panic!("unexpected verdict: {other}"),
                    }
                }
                verdicts
            }));
        }

        // control-plane churn concurrent with the load above
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert_eq!(reg.swap_quarantined("churn", Arc::new(VersionEcho(2))).unwrap(), 2);
        assert!(
            reg.swap_quarantined("churn", Arc::new(Exploding)).is_err(),
            "broken candidate must not survive quarantine"
        );
        std::thread::sleep(std::time::Duration::from_millis(2));
        reg.retire("ephemeral").unwrap();
        reg.register("ephemeral", Arc::new(VersionEcho(1)), &cfg).unwrap();
        assert_eq!(reg.swap_quarantined("churn", Arc::new(VersionEcho(3))).unwrap(), 3);

        let mut verdicts = 0usize;
        for j in joins {
            verdicts += j.join().unwrap();
        }
        assert_eq!(
            verdicts,
            n_threads * per_thread,
            "every request must produce exactly one verdict"
        );
        let fleet = reg.shutdown();
        assert_eq!(fleet.models["churn"].version, 3);
        assert_eq!(fleet.models["stable"].version, 1);
        fleet.assert_multiplier_less();
    });
}

#[test]
fn prop_f16_roundtrip_monotone_and_exact() {
    forall("f16-codec", 200, |rng| {
        // exactness on decode->encode
        let bits = (rng.next_u64() & 0x7BFF) as u16; // finite values
        let x = F16(bits).to_f32();
        assert_eq!(F16::from_f32(x).0, bits);
        // monotone encode on positives
        let a = rng.f32() * 100.0;
        let c = a * (1.0 + rng.f32() * 0.5) + 1e-3;
        let fa = F16::from_f32(a).0;
        let fc = F16::from_f32(c).0;
        assert!(fa <= fc, "encode not monotone: {a} -> {fa:#x}, {c} -> {fc:#x}");
    });
}

#[test]
fn prop_plan_json_roundtrip() {
    forall("plan-json", 150, |rng| {
        let n_layers = 1 + rng.below(5);
        let affine: Vec<AffineMode> = (0..n_layers)
            .map(|_| match rng.below(3) {
                0 => AffineMode::WholeFixed {
                    bits: 1 + rng.below(16) as u32,
                    m: 1 + rng.below(8),
                    range_exp: rng.below(9) as i32 - 4,
                },
                1 => AffineMode::BitplaneFixed {
                    bits: 1 + rng.below(16) as u32,
                    m: 1 + rng.below(8),
                    range_exp: rng.below(9) as i32 - 4,
                },
                _ => AffineMode::Float { planes: 1 + rng.below(11) as u32, m: 1 + rng.below(4) },
            })
            .collect();
        let plan = EnginePlan {
            affine,
            fallback: AffineMode::Float { planes: 11, m: 1 },
            r_o: 8 + rng.below(24) as u32,
        };
        let text = plan_to_json(&plan).to_string_pretty();
        let back = plan_from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, plan);
    });
}

#[test]
fn prop_json_parse_never_panics_on_mutations() {
    // fuzz-ish: random mutations of valid JSON parse or error, never panic
    forall("json-fuzz", 300, |rng| {
        let base = r#"{"a": [1, 2.5, "x"], "b": {"c": true, "d": null}}"#;
        let mut bytes = base.as_bytes().to_vec();
        for _ in 0..1 + rng.below(4) {
            let i = rng.below(bytes.len());
            bytes[i] = (rng.next_u64() & 0x7F) as u8;
        }
        if let Ok(s) = std::str::from_utf8(&bytes) {
            let _ = Json::parse(s); // Ok or Err; must not panic
        }
    });
}

#[test]
fn prop_stochastic_rounding_unbiased() {
    forall("stochastic-unbiased", 40, |rng| {
        let in_bits = 6 + rng.below(3) as u32;
        let out_bits = 2 + rng.below(3) as u32;
        let r = StochasticRounder::new(in_bits, out_bits, 2048, rng.next_u64());
        let drop = in_bits - out_bits;
        let code = rng.below((1 << in_bits) - (1 << drop)) as u32;
        let mean: f64 = (0..2048).map(|p| r.round_at(code, p) as f64).sum::<f64>() / 2048.0;
        let expect = code as f64 / (1 << drop) as f64;
        assert!(
            (mean - expect).abs() < 0.05,
            "in={in_bits} out={out_bits} code={code}: mean {mean} expect {expect}"
        );
    });
}

#[test]
fn prop_quantizer_error_bound_and_monotonicity() {
    forall("fixed-quant", 300, |rng| {
        let bits = 1 + rng.below(8) as u32;
        let fmt = FixedFormat::new(bits);
        let a = rng.f32();
        let b = rng.f32();
        let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
        assert!(fmt.quantize(lo) <= fmt.quantize(hi));
        let err = (fmt.fake_quant(a) - a).abs();
        assert!(err <= 1.0 / (1u32 << bits) as f32 + 1e-6);
    });
}

#[test]
fn prop_bits_ladder_accuracy_is_roughly_monotone() {
    // A trained toy classifier's LUT accuracy should not collapse as
    // precision increases (allowing small non-monotonic wiggle — the
    // paper itself observes slight decreases).
    use tablenet::data::synth::{generate, Kind};
    use tablenet::data::Split;
    use tablenet::engine::Compiler;
    use tablenet::train::{train_dense, TrainConfig};

    let (px, lb) = generate(Kind::Digits, 500, 33);
    let train = Split {
        images: px.iter().map(|&v| v as f32 / 255.0).collect(),
        labels: lb.iter().map(|&v| v as usize).collect(),
    };
    let (tpx, tlb) = generate(Kind::Digits, 150, 44);
    let test = Split {
        images: tpx.iter().map(|&v| v as f32 / 255.0).collect(),
        labels: tlb.iter().map(|&v| v as usize).collect(),
    };
    let model = train_dense(
        &train,
        &[784, 10],
        &TrainConfig { steps: 250, lr: 0.3, ..Default::default() },
    );
    let mut accs = Vec::new();
    for bits in [1u32, 3, 6] {
        let plan = EnginePlan {
            affine: vec![AffineMode::BitplaneFixed { bits, m: 14, range_exp: 0 }],
            fallback: AffineMode::Float { planes: 11, m: 1 },
            r_o: 16,
        };
        let lut = Compiler::new(&model).plan(&plan).build().unwrap();
        let (acc, _) = lut.accuracy(&test.images, 784, &test.labels);
        accs.push(acc);
    }
    assert!(accs[1] + 0.05 >= accs[0], "{accs:?}");
    assert!(accs[2] + 0.05 >= accs[1], "{accs:?}");
}
