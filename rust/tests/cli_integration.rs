//! CLI integration: drives the `tablenet` binary end-to-end the way a
//! user would (gen-data, train, eval, plan, sweeps) in a temp sandbox.

use std::path::PathBuf;
use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_tablenet"))
}

fn sandbox(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("tablenet_cli_{tag}"));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn help_lists_commands() {
    let out = bin().arg("help").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for cmd in [
        "gen-data",
        "train",
        "compile",
        "inspect",
        "eval",
        "sweep-bits",
        "sweep-partitions",
        "serve",
        "client",
    ] {
        assert!(text.contains(cmd), "help missing {cmd}");
    }
    assert!(text.contains("--artifact"), "help missing --artifact flag");
    assert!(text.contains("--no-fuse"), "help missing --no-fuse flag");
    assert!(text.contains("--swap"), "help missing --swap flag");
    assert!(text.contains("--watch-dir"), "help missing --watch-dir flag");
    assert!(text.contains("--listen"), "help missing --listen flag");
    assert!(text.contains("--admission-budget"), "help missing --admission-budget flag");
}

#[test]
fn unknown_command_fails_with_message() {
    let out = bin().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown subcommand"));
}

#[test]
fn gen_data_writes_idx_files() {
    let dir = sandbox("gendata");
    let out = bin()
        .args(["gen-data", "--dir"])
        .arg(dir.join("synth"))
        .args(["--train", "60", "--test", "20"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    for f in [
        "train-images-idx3-ubyte",
        "t10k-labels-idx1-ubyte",
        "fashion-train-images-idx3-ubyte",
    ] {
        assert!(dir.join("synth").join(f).exists(), "missing {f}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn train_then_eval_roundtrip() {
    let dir = sandbox("traineval");
    let weights = dir.join("w.bin");
    let out = bin()
        .args(["train", "--arch", "linear", "--steps", "400", "--dir"])
        .arg(dir.join("synth"))
        .args(["--train", "800", "--test", "200", "--out"])
        .arg(&weights)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(weights.exists());

    let out = bin()
        .args(["eval", "--arch", "linear", "--weights"])
        .arg(&weights)
        .args(["--dir"])
        .arg(dir.join("synth"))
        .args(["--train", "800", "--test", "200", "--n", "100"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("LUT engine"));
    assert!(text.contains("mults=0"), "eval must report zero multiplies: {text}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn compile_then_eval_artifact_is_bit_identical_to_weights() {
    let dir = sandbox("compileeval");
    let weights = dir.join("w.bin");
    let out = bin()
        .args(["train", "--arch", "linear", "--steps", "400", "--dir"])
        .arg(dir.join("synth"))
        .args(["--train", "800", "--test", "200", "--out"])
        .arg(&weights)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    // compile weights -> .ltm artifact
    let ltm = dir.join("model.ltm");
    let out = bin()
        .args(["compile", "--arch", "linear", "--weights"])
        .arg(&weights)
        .args(["--out"])
        .arg(&ltm)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(ltm.exists(), "compile did not write the artifact");

    // eval from weights and from the artifact: the LUT engine line
    // (accuracy, size, per-inference counters) must be IDENTICAL
    let eval = |extra: &[&std::ffi::OsStr]| -> String {
        let mut cmd = bin();
        cmd.args(["eval", "--arch", "linear", "--dir"])
            .arg(dir.join("synth"))
            .args(["--train", "800", "--test", "200", "--n", "100", "--weights"])
            .arg(&weights);
        for a in extra {
            cmd.arg(a);
        }
        let out = cmd.output().unwrap();
        assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
        let text = String::from_utf8_lossy(&out.stdout).to_string();
        text.lines()
            .find(|l| l.starts_with("LUT engine:"))
            .unwrap_or_else(|| panic!("no LUT engine line in: {text}"))
            .to_string()
    };
    let from_weights = eval(&[]);
    let flag = std::ffi::OsString::from("--artifact");
    let from_artifact = eval(&[flag.as_os_str(), ltm.as_os_str()]);
    assert_eq!(
        from_weights, from_artifact,
        "artifact-served engine diverged from weight-compiled engine"
    );
    assert!(from_weights.contains("mults=0"), "{from_weights}");

    // serve can start from the artifact alone (no --weights) and the
    // whole run stays multiplier-less; dataset-driven load via --dir
    let out = bin()
        .args(["serve", "--artifact"])
        .arg(&ltm)
        .args(["--dir"])
        .arg(dir.join("synth"))
        .args(["--train", "800", "--test", "200", "--requests", "40", "--clients", "2"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("loaded artifact"), "{text}");
    assert!(text.contains("mults=0"), "serve run must report zero multiplies: {text}");
    assert!(text.contains("accuracy"), "dataset-driven serve must report accuracy: {text}");

    // pure-push: TWO named models from artifacts alone — no --dir, no
    // weights, request rows synthesized from the artifact's own input
    // geometry — with a mid-run hot swap
    let spec_a = format!("a={}", ltm.display());
    let spec_b = format!("b={}", ltm.display());
    let swap_a = format!("a={}", ltm.display());
    let out = bin()
        .args(["serve", "--artifact", &spec_a, "--artifact", &spec_b])
        .args(["--swap", &swap_a])
        .args(["--requests", "60", "--clients", "2", "--max-batch", "8"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("pure-push"), "{text}");
    assert!(text.contains("[a v2"), "swap must bump 'a' to v2: {text}");
    assert!(text.contains("[b v1"), "'b' must stay at v1: {text}");
    assert!(text.contains("fleet: 2 models"), "{text}");
    assert!(text.contains("mults=0"), "pure-push serve must be multiplier-less: {text}");
    assert!(!text.contains("accuracy"), "pure-push has no labels: {text}");

    // inspect dumps the artifact through the same parse path serve
    // loads with: v2 container, per-stage fnv checksums, storage
    // residency
    let out = bin().arg("inspect").arg(&ltm).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("container version : 2"), "{text}");
    assert!(text.contains("dense-bitplane"), "{text}");
    assert!(text.contains("input features    : 784"), "{text}");
    assert!(text.contains("fnv 0x"), "per-stage checksums missing: {text}");
    assert!(text.contains("bitplane_fixed"), "plan JSON missing: {text}");
    #[cfg(unix)]
    assert!(
        text.contains("borrowed(mmap)"),
        "mapped inspect must report borrowed arenas: {text}"
    );

    // corrupted artifact must be rejected, not served
    let mut bytes = std::fs::read(&ltm).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x40;
    let bad = dir.join("bad.ltm");
    std::fs::write(&bad, &bytes).unwrap();
    let out = bin()
        .args(["eval", "--arch", "linear", "--dir"])
        .arg(dir.join("synth"))
        .args(["--train", "800", "--test", "200", "--weights"])
        .arg(&weights)
        .args(["--artifact"])
        .arg(&bad)
        .output()
        .unwrap();
    assert!(!out.status.success(), "corrupted artifact was accepted");
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("checksum"),
        "{}",
        String::from_utf8_lossy(&out.stderr)
    );
    // inspect goes through the same checksum gate
    let out = bin().arg("inspect").arg(&bad).output().unwrap();
    assert!(!out.status.success(), "inspect accepted a corrupted artifact");
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("checksum"),
        "{}",
        String::from_utf8_lossy(&out.stderr)
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// Train a quick linear model and compile it to `<tag>.ltm` inside
/// `dir` (synthetic data cached under `dir/synth`). `seed` varies the
/// weights so two calls produce artifacts with different content.
fn train_and_compile(dir: &std::path::Path, tag: &str, seed: u64) -> PathBuf {
    let weights = dir.join(format!("{tag}.bin"));
    let seed = seed.to_string();
    let out = bin()
        .args(["train", "--arch", "linear", "--steps", "250", "--dir"])
        .arg(dir.join("synth"))
        .args(["--train", "400", "--test", "100", "--seed", seed.as_str(), "--out"])
        .arg(&weights)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let ltm = dir.join(format!("{tag}.ltm"));
    let out = bin()
        .args(["compile", "--arch", "linear", "--weights"])
        .arg(&weights)
        .args(["--out"])
        .arg(&ltm)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    ltm
}

#[test]
fn compile_no_fuse_flag_and_fusion_banner() {
    // linear has a single bank and no elementwise stages, so the
    // optimizer has nothing to fold: the fused and unfused artifacts
    // must be byte-identical (the epilogue encoding appends nothing
    // when a bank carries no chain — pre-fusion readers stay
    // compatible), while the compile banner reports the fusion mode
    let dir = sandbox("nofuse");
    let weights = dir.join("w.bin");
    let out = bin()
        .args(["train", "--arch", "linear", "--steps", "250", "--dir"])
        .arg(dir.join("synth"))
        .args(["--train", "400", "--test", "100", "--out"])
        .arg(&weights)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    let fused = dir.join("fused.ltm");
    let out = bin()
        .args(["compile", "--arch", "linear", "--weights"])
        .arg(&weights)
        .args(["--out"])
        .arg(&fused)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(
        text.contains("fusion: on, no foldable elementwise chains"),
        "compile banner must report the fusion outcome: {text}"
    );

    let unfused = dir.join("unfused.ltm");
    let out = bin()
        .args(["compile", "--arch", "linear", "--weights"])
        .arg(&weights)
        .args(["--out"])
        .arg(&unfused)
        .arg("--no-fuse")
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("fusion: disabled (--no-fuse)"), "{text}");

    assert_eq!(
        std::fs::read(&fused).unwrap(),
        std::fs::read(&unfused).unwrap(),
        "chainless pipeline must compile to identical bytes either way"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn inspect_corrupted_artifact_exits_nonzero_naming_stage_and_offset() {
    let dir = sandbox("inspectbad");
    let ltm = train_and_compile(&dir, "model", 11);

    // flip one byte near the end of the file: with the v2 layout that
    // is inside the LAST stage's payload, and the failure must name
    // the stage and its file offset — not a bare parse error
    let mut bytes = std::fs::read(&ltm).unwrap();
    let n = bytes.len();
    bytes[n - 2] ^= 0x08;
    let bad = dir.join("bad.ltm");
    std::fs::write(&bad, &bytes).unwrap();

    let out = bin().arg("inspect").arg(&bad).output().unwrap();
    assert!(!out.status.success(), "inspect accepted a corrupted v2 artifact");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("checksum mismatch"), "{err}");
    assert!(err.contains("stage "), "error must name the failing stage: {err}");
    assert!(err.contains("offset 0x"), "error must give the file offset: {err}");

    // truncation is equally localised
    let cut = dir.join("cut.ltm");
    std::fs::write(&cut, &std::fs::read(&ltm).unwrap()[..n - 16]).unwrap();
    let out = bin().arg("inspect").arg(&cut).output().unwrap();
    assert!(!out.status.success(), "inspect accepted a truncated artifact");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("stage "), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn serve_rejects_corrupt_mid_run_swap_keeps_incumbent_and_exits_nonzero() {
    let dir = sandbox("swapbad");
    let ltm = train_and_compile(&dir, "model", 31);
    // flip one payload byte: the header still parses, the per-stage
    // checksum fails at load time — exactly what a half-written deploy
    // handed to --swap looks like
    let mut bytes = std::fs::read(&ltm).unwrap();
    let n = bytes.len();
    bytes[n - 2] ^= 0x08;
    let bad = dir.join("bad.ltm");
    std::fs::write(&bad, &bytes).unwrap();

    let spec = format!("m={}", ltm.display());
    let swap = format!("m={}", bad.display());
    let out = bin()
        .args(["serve", "--artifact", &spec, "--swap", &swap])
        .args(["--requests", "60", "--clients", "2", "--max-batch", "8"])
        .output()
        .unwrap();
    let text = String::from_utf8_lossy(&out.stdout);
    let err = String::from_utf8_lossy(&out.stderr);
    // the full load is served by the incumbent at v1 — a bad candidate
    // must degrade the DEPLOY, never the serving...
    assert!(text.contains("served 60 requests"), "{text}\n{err}");
    assert!(text.contains("[m v1"), "incumbent must keep serving at v1: {text}");
    assert!(text.contains("mults=0"), "{text}");
    // ...and the run still exits non-zero, naming the failing stage
    // (not a panic, not a silent success)
    assert!(!out.status.success(), "corrupt mid-run swap must fail the run: {text}");
    assert!(err.contains("checksum mismatch"), "{err}");
    assert!(err.contains("stage "), "error must name the failing stage: {err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn serve_watch_dir_rolls_deploys_without_restart() {
    let dir = sandbox("watchdir");
    let m1 = train_and_compile(&dir, "gen1", 21);
    let m2 = train_and_compile(&dir, "gen2", 22);
    assert_ne!(
        std::fs::read(&m1).unwrap(),
        std::fs::read(&m2).unwrap(),
        "need two distinct artifacts for the rolling deploy"
    );
    let watch = dir.join("deploy");
    std::fs::create_dir_all(&watch).unwrap();

    // start serving an EMPTY watch dir: no --artifact, no weights, no
    // restart ever — the fleet is whatever the directory says.
    // --client-delay-ms paces the load so the run outlives both deploys.
    let mut child = bin()
        .args(["serve", "--watch-dir"])
        .arg(&watch)
        .args(["--watch-interval-ms", "50", "--requests", "600", "--clients", "2"])
        .args(["--client-delay-ms", "5", "--max-batch", "8"])
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::piped())
        .spawn()
        .unwrap();

    // deploy generation 1, then replace it with generation 2 mid-load —
    // that is the whole deploy interface. Copy-to-temp + rename is the
    // atomic pattern replacing a LIVE model requires: the old version
    // keeps serving from a mapping of the old inode, so the watch-dir
    // entry must never be a half-written (or in-place-truncated) file.
    let deploy = |src: &PathBuf| {
        let tmp = watch.join("live.ltm.tmp");
        std::fs::copy(src, &tmp).unwrap();
        std::fs::rename(&tmp, watch.join("live.ltm")).unwrap();
    };
    std::thread::sleep(std::time::Duration::from_millis(400));
    deploy(&m1);
    std::thread::sleep(std::time::Duration::from_millis(700));
    deploy(&m2);

    let out = child.wait_with_output().unwrap();
    let text = String::from_utf8_lossy(&out.stdout).to_string();
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "serve --watch-dir failed: {err}\n{text}");
    assert!(text.contains("registered model 'live'"), "{text}");
    assert!(
        text.contains("swapped model 'live' -> v2"),
        "rolling deploy not observed: {text}"
    );
    assert!(text.contains("served 600 requests"), "{text}");
    assert!(text.contains("mults=0"), "watch-dir serve must stay multiplier-less: {text}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn serve_without_listen_is_pure_push_unchanged() {
    // backward-compat: --listen is strictly additive. Without it serve
    // must never open a socket and the push-mode output is unchanged —
    // no listen banner, no wire ledger, same served-N summary line.
    let dir = sandbox("nolisten");
    let ltm = train_and_compile(&dir, "model", 41);
    let spec = format!("m={}", ltm.display());
    let out = bin()
        .args(["serve", "--artifact", &spec])
        .args(["--requests", "40", "--clients", "2", "--max-batch", "8"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("pure-push"), "{text}");
    assert!(text.contains("served 40 requests"), "{text}");
    assert!(text.contains("mults=0"), "{text}");
    assert!(!text.contains("listening on"), "no socket without --listen: {text}");
    assert!(!text.contains("over the wire"), "no wire ledger without --listen: {text}");
    std::fs::remove_dir_all(&dir).ok();
}

#[cfg(unix)]
#[test]
fn serve_listen_end_to_end_with_wire_client() {
    use std::io::{BufRead, Read};

    let dir = sandbox("listen");
    let ltm = train_and_compile(&dir, "model", 42);
    let spec = format!("live={}", ltm.display());
    // --listen 127.0.0.1:0 binds an ephemeral port; the server prints
    // the resolved address in its banner, so scrape it from stdout
    let mut child = bin()
        .args(["serve", "--artifact", &spec])
        .args(["--listen", "127.0.0.1:0", "--net-threads", "1", "--requests", "96"])
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::piped())
        .spawn()
        .unwrap();
    let mut stdout = std::io::BufReader::new(child.stdout.take().unwrap());
    let mut banner = String::new();
    let addr = loop {
        let mut line = String::new();
        if stdout.read_line(&mut line).unwrap() == 0 {
            let _ = child.kill();
            panic!("serve exited before the listen banner:\n{banner}");
        }
        banner.push_str(&line);
        if let Some(rest) = line.strip_prefix("listening on ") {
            break rest.split(' ').next().unwrap().trim().to_string();
        }
    };

    let out = bin()
        .args(["client", "--addr", &addr, "--model", "live"])
        .args(["--requests", "96", "--connections", "2", "--rows-per-frame", "8"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let ctext = String::from_utf8_lossy(&out.stdout);
    assert!(ctext.contains("lost 0"), "client lost rows: {ctext}");

    // the 96 rows the client sent are exactly the drain threshold: the
    // server exits zero with the wire ledger balanced
    let mut rest = String::new();
    stdout.read_to_string(&mut rest).unwrap();
    let status = child.wait().unwrap();
    assert!(status.success(), "serve --listen failed:\n{banner}{rest}");
    assert!(rest.contains("net accounting: exact"), "{rest}");
    assert!(rest.contains("served 96 rows over the wire"), "{rest}");
    assert!(rest.contains("mults=0"), "{rest}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn plan_reports_paper_numbers() {
    let out = bin().arg("plan").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("17.50 MiB"), "{text}");
    assert!(text.contains("14652918"), "{text}");
    assert!(text.contains("2320"), "{text}");
}

#[test]
fn sweep_partitions_planner_only_works_without_weights() {
    let dir = sandbox("sweep");
    let out = bin()
        .args(["sweep-partitions", "--arch", "mlp", "--weights", "/nonexistent.bin"])
        .args(["--dir"])
        .arg(dir.join("synth"))
        .args(["--train", "60", "--test", "20"])
        .args(["--csv-out"])
        .arg(dir.join("fig7.csv"))
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let csv = std::fs::read_to_string(dir.join("fig7.csv")).unwrap();
    assert!(csv.lines().count() > 5);
    assert!(csv.starts_with("config,"));
    std::fs::remove_dir_all(&dir).ok();
}
