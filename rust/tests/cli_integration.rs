//! CLI integration: drives the `tablenet` binary end-to-end the way a
//! user would (gen-data, train, eval, plan, sweeps) in a temp sandbox.

use std::path::PathBuf;
use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_tablenet"))
}

fn sandbox(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("tablenet_cli_{tag}"));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn help_lists_commands() {
    let out = bin().arg("help").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for cmd in [
        "gen-data",
        "train",
        "compile",
        "inspect",
        "eval",
        "sweep-bits",
        "sweep-partitions",
        "serve",
    ] {
        assert!(text.contains(cmd), "help missing {cmd}");
    }
    assert!(text.contains("--artifact"), "help missing --artifact flag");
    assert!(text.contains("--swap"), "help missing --swap flag");
}

#[test]
fn unknown_command_fails_with_message() {
    let out = bin().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown subcommand"));
}

#[test]
fn gen_data_writes_idx_files() {
    let dir = sandbox("gendata");
    let out = bin()
        .args(["gen-data", "--dir"])
        .arg(dir.join("synth"))
        .args(["--train", "60", "--test", "20"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    for f in [
        "train-images-idx3-ubyte",
        "t10k-labels-idx1-ubyte",
        "fashion-train-images-idx3-ubyte",
    ] {
        assert!(dir.join("synth").join(f).exists(), "missing {f}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn train_then_eval_roundtrip() {
    let dir = sandbox("traineval");
    let weights = dir.join("w.bin");
    let out = bin()
        .args(["train", "--arch", "linear", "--steps", "400", "--dir"])
        .arg(dir.join("synth"))
        .args(["--train", "800", "--test", "200", "--out"])
        .arg(&weights)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(weights.exists());

    let out = bin()
        .args(["eval", "--arch", "linear", "--weights"])
        .arg(&weights)
        .args(["--dir"])
        .arg(dir.join("synth"))
        .args(["--train", "800", "--test", "200", "--n", "100"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("LUT engine"));
    assert!(text.contains("mults=0"), "eval must report zero multiplies: {text}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn compile_then_eval_artifact_is_bit_identical_to_weights() {
    let dir = sandbox("compileeval");
    let weights = dir.join("w.bin");
    let out = bin()
        .args(["train", "--arch", "linear", "--steps", "400", "--dir"])
        .arg(dir.join("synth"))
        .args(["--train", "800", "--test", "200", "--out"])
        .arg(&weights)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    // compile weights -> .ltm artifact
    let ltm = dir.join("model.ltm");
    let out = bin()
        .args(["compile", "--arch", "linear", "--weights"])
        .arg(&weights)
        .args(["--out"])
        .arg(&ltm)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(ltm.exists(), "compile did not write the artifact");

    // eval from weights and from the artifact: the LUT engine line
    // (accuracy, size, per-inference counters) must be IDENTICAL
    let eval = |extra: &[&std::ffi::OsStr]| -> String {
        let mut cmd = bin();
        cmd.args(["eval", "--arch", "linear", "--dir"])
            .arg(dir.join("synth"))
            .args(["--train", "800", "--test", "200", "--n", "100", "--weights"])
            .arg(&weights);
        for a in extra {
            cmd.arg(a);
        }
        let out = cmd.output().unwrap();
        assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
        let text = String::from_utf8_lossy(&out.stdout).to_string();
        text.lines()
            .find(|l| l.starts_with("LUT engine:"))
            .unwrap_or_else(|| panic!("no LUT engine line in: {text}"))
            .to_string()
    };
    let from_weights = eval(&[]);
    let flag = std::ffi::OsString::from("--artifact");
    let from_artifact = eval(&[flag.as_os_str(), ltm.as_os_str()]);
    assert_eq!(
        from_weights, from_artifact,
        "artifact-served engine diverged from weight-compiled engine"
    );
    assert!(from_weights.contains("mults=0"), "{from_weights}");

    // serve can start from the artifact alone (no --weights) and the
    // whole run stays multiplier-less; dataset-driven load via --dir
    let out = bin()
        .args(["serve", "--artifact"])
        .arg(&ltm)
        .args(["--dir"])
        .arg(dir.join("synth"))
        .args(["--train", "800", "--test", "200", "--requests", "40", "--clients", "2"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("loaded artifact"), "{text}");
    assert!(text.contains("mults=0"), "serve run must report zero multiplies: {text}");
    assert!(text.contains("accuracy"), "dataset-driven serve must report accuracy: {text}");

    // pure-push: TWO named models from artifacts alone — no --dir, no
    // weights, request rows synthesized from the artifact's own input
    // geometry — with a mid-run hot swap
    let spec_a = format!("a={}", ltm.display());
    let spec_b = format!("b={}", ltm.display());
    let swap_a = format!("a={}", ltm.display());
    let out = bin()
        .args(["serve", "--artifact", &spec_a, "--artifact", &spec_b])
        .args(["--swap", &swap_a])
        .args(["--requests", "60", "--clients", "2", "--max-batch", "8"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("pure-push"), "{text}");
    assert!(text.contains("[a v2"), "swap must bump 'a' to v2: {text}");
    assert!(text.contains("[b v1"), "'b' must stay at v1: {text}");
    assert!(text.contains("fleet: 2 models"), "{text}");
    assert!(text.contains("mults=0"), "pure-push serve must be multiplier-less: {text}");
    assert!(!text.contains("accuracy"), "pure-push has no labels: {text}");

    // inspect dumps the artifact through the same parse path serve
    // loads with
    let out = bin().arg("inspect").arg(&ltm).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("container version : 1"), "{text}");
    assert!(text.contains("dense-bitplane"), "{text}");
    assert!(text.contains("input features    : 784"), "{text}");
    assert!(text.contains("bitplane_fixed"), "plan JSON missing: {text}");

    // corrupted artifact must be rejected, not served
    let mut bytes = std::fs::read(&ltm).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x40;
    let bad = dir.join("bad.ltm");
    std::fs::write(&bad, &bytes).unwrap();
    let out = bin()
        .args(["eval", "--arch", "linear", "--dir"])
        .arg(dir.join("synth"))
        .args(["--train", "800", "--test", "200", "--weights"])
        .arg(&weights)
        .args(["--artifact"])
        .arg(&bad)
        .output()
        .unwrap();
    assert!(!out.status.success(), "corrupted artifact was accepted");
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("checksum"),
        "{}",
        String::from_utf8_lossy(&out.stderr)
    );
    // inspect goes through the same checksum gate
    let out = bin().arg("inspect").arg(&bad).output().unwrap();
    assert!(!out.status.success(), "inspect accepted a corrupted artifact");
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("checksum"),
        "{}",
        String::from_utf8_lossy(&out.stderr)
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn plan_reports_paper_numbers() {
    let out = bin().arg("plan").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("17.50 MiB"), "{text}");
    assert!(text.contains("14652918"), "{text}");
    assert!(text.contains("2320"), "{text}");
}

#[test]
fn sweep_partitions_planner_only_works_without_weights() {
    let dir = sandbox("sweep");
    let out = bin()
        .args(["sweep-partitions", "--arch", "mlp", "--weights", "/nonexistent.bin"])
        .args(["--dir"])
        .arg(dir.join("synth"))
        .args(["--train", "60", "--test", "20"])
        .args(["--csv-out"])
        .arg(dir.join("fig7.csv"))
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let csv = std::fs::read_to_string(dir.join("fig7.csv")).unwrap();
    assert!(csv.lines().count() > 5);
    assert!(csv.starts_with("config,"));
    std::fs::remove_dir_all(&dir).ok();
}
