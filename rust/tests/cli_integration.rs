//! CLI integration: drives the `tablenet` binary end-to-end the way a
//! user would (gen-data, train, eval, plan, sweeps) in a temp sandbox.

use std::path::PathBuf;
use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_tablenet"))
}

fn sandbox(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("tablenet_cli_{tag}"));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn help_lists_commands() {
    let out = bin().arg("help").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for cmd in ["gen-data", "train", "eval", "sweep-bits", "sweep-partitions", "serve"] {
        assert!(text.contains(cmd), "help missing {cmd}");
    }
}

#[test]
fn unknown_command_fails_with_message() {
    let out = bin().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown subcommand"));
}

#[test]
fn gen_data_writes_idx_files() {
    let dir = sandbox("gendata");
    let out = bin()
        .args(["gen-data", "--dir"])
        .arg(dir.join("synth"))
        .args(["--train", "60", "--test", "20"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    for f in [
        "train-images-idx3-ubyte",
        "t10k-labels-idx1-ubyte",
        "fashion-train-images-idx3-ubyte",
    ] {
        assert!(dir.join("synth").join(f).exists(), "missing {f}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn train_then_eval_roundtrip() {
    let dir = sandbox("traineval");
    let weights = dir.join("w.bin");
    let out = bin()
        .args(["train", "--arch", "linear", "--steps", "400", "--dir"])
        .arg(dir.join("synth"))
        .args(["--train", "800", "--test", "200", "--out"])
        .arg(&weights)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(weights.exists());

    let out = bin()
        .args(["eval", "--arch", "linear", "--weights"])
        .arg(&weights)
        .args(["--dir"])
        .arg(dir.join("synth"))
        .args(["--train", "800", "--test", "200", "--n", "100"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("LUT engine"));
    assert!(text.contains("mults=0"), "eval must report zero multiplies: {text}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn plan_reports_paper_numbers() {
    let out = bin().arg("plan").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("17.50 MiB"), "{text}");
    assert!(text.contains("14652918"), "{text}");
    assert!(text.contains("2320"), "{text}");
}

#[test]
fn sweep_partitions_planner_only_works_without_weights() {
    let dir = sandbox("sweep");
    let out = bin()
        .args(["sweep-partitions", "--arch", "mlp", "--weights", "/nonexistent.bin"])
        .args(["--dir"])
        .arg(dir.join("synth"))
        .args(["--train", "60", "--test", "20"])
        .args(["--csv-out"])
        .arg(dir.join("fig7.csv"))
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let csv = std::fs::read_to_string(dir.join("fig7.csv")).unwrap();
    assert!(csv.lines().count() > 5);
    assert!(csv.starts_with("config,"));
    std::fs::remove_dir_all(&dir).ok();
}
