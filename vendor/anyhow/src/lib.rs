//! Offline, dependency-free shim implementing the subset of the
//! `anyhow` API this workspace uses: [`Error`], [`Result`], the
//! [`Context`] extension trait and the `anyhow!` / `bail!` / `ensure!`
//! macros. Semantics match upstream anyhow for that subset:
//!
//! * `?` converts any `E: std::error::Error + Send + Sync + 'static`
//!   into [`Error`] (the source chain is preserved as context frames);
//! * `{:#}` formats the whole context chain `outer: inner: ...`;
//! * `{:?}` formats the anyhow-style `outer\n\nCaused by:\n    inner`.
//!
//! The shim exists because the build must work with no network access;
//! it can be deleted in favour of the real crate wherever a registry is
//! available (the API is a strict subset, nothing else changes).

use std::fmt;

/// Context-carrying error: a message plus an optional cause chain.
pub struct Error {
    msg: String,
    cause: Option<Box<Error>>,
}

/// `anyhow::Result<T>` — `std::result::Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct from any displayable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string(), cause: None }
    }

    /// Wrap `self` with an outer context message.
    fn wrap(self, ctx: String) -> Error {
        Error { msg: ctx, cause: Some(Box::new(self)) }
    }

    /// The outermost message.
    pub fn to_string_outer(&self) -> &str {
        &self.msg
    }

    /// Iterate the context chain, outermost first.
    pub fn chain(&self) -> Chain<'_> {
        Chain { next: Some(self) }
    }

    /// The innermost error in the chain.
    pub fn root_cause(&self) -> &Error {
        let mut cur = self;
        while let Some(c) = &cur.cause {
            cur = c;
        }
        cur
    }
}

/// Iterator over the context chain of an [`Error`].
pub struct Chain<'a> {
    next: Option<&'a Error>,
}

impl<'a> Iterator for Chain<'a> {
    type Item = &'a Error;
    fn next(&mut self) -> Option<&'a Error> {
        let cur = self.next?;
        self.next = cur.cause.as_deref();
        Some(cur)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the full chain, colon-separated (anyhow's format)
            let mut first = true;
            for e in self.chain() {
                if !first {
                    write!(f, ": ")?;
                }
                write!(f, "{}", e.msg)?;
                first = false;
            }
            Ok(())
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if self.cause.is_some() {
            write!(f, "\n\nCaused by:")?;
            for e in self.chain().skip(1) {
                write!(f, "\n    {}", e.msg)?;
            }
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`,
// exactly like the real anyhow::Error — that is what makes the blanket
// `From` below coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // flatten the std source chain into context frames
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut err: Option<Error> = None;
        for msg in msgs.into_iter().rev() {
            err = Some(Error { msg, cause: err.map(Box::new) });
        }
        err.expect("at least one message")
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).wrap(ctx.to_string()))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).wrap(f().to_string()))
    }
}

impl<T> Context<T, Error> for Result<T, Error> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.map_err(|e| e.wrap(ctx.to_string()))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.wrap(f().to_string()))
    }
}

impl<T> Context<T, core::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(ctx))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return with an error built like `anyhow!`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Assert a condition, early-returning an error when it fails.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert_eq!(format!("{e}"), "missing file");
    }

    #[test]
    fn context_chains_and_alternate_format() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("loading weights").unwrap_err();
        assert_eq!(format!("{e}"), "loading weights");
        assert_eq!(format!("{e:#}"), "loading weights: missing file");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn with_context_on_anyhow_result() {
        let r: Result<()> = Err(anyhow!("inner {}", 7));
        let e = r.with_context(|| "outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner 7");
        assert_eq!(e.root_cause().to_string_outer(), "inner 7");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x > 100 {
                bail!("too big");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(format!("{}", f(-1).unwrap_err()), "negative: -1");
        assert_eq!(format!("{}", f(200).unwrap_err()), "too big");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("absent").unwrap_err();
        assert_eq!(format!("{e}"), "absent");
    }
}
