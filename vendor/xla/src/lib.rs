//! Offline stub of the `xla` (PJRT) bindings. It exposes exactly the
//! surface `tablenet::runtime` compiles against and fails at *runtime*
//! with a clear error, so the whole workspace builds on machines with
//! no XLA toolchain (CI, fresh clones, air-gapped containers).
//!
//! On a machine with the real bindings, point the `xla` path dependency
//! in the workspace `Cargo.toml` at them; `tablenet::runtime` is written
//! against the real API and needs no changes.

use std::fmt;

/// Stub error: every entry point returns this.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn stub<T>() -> Result<T> {
    Err(Error(
        "xla stub: PJRT is unavailable in this build; vendor the real \
         `xla` crate to run the reference backend"
            .to_string(),
    ))
}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        stub()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        stub()
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<std::path::Path>>(_path: P) -> Result<HloModuleProto> {
        stub()
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Loaded executable (stub: unreachable because compile() fails).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        stub()
    }
}

/// Device buffer handle (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        stub()
    }
}

/// Host literal (stub).
pub struct Literal;

impl Literal {
    pub fn vec1<T>(_values: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        stub()
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        stub()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        stub()
    }
}
