//! Fig. 6 — "Number of bits in input versus accuracy on Fashion MNIST
//! data using a linear classifier."
//!
//! Same harness as Fig. 4 on the fashion corpus; the paper's headline
//! phenomena to reproduce are (a) the ~3-bit accuracy plateau and
//! (b) a lower absolute band than digits, with (c) occasional slight
//! accuracy *decrease* at high precision (quantization-as-regulariser).

mod common;

use tablenet::data::synth::Kind;
use tablenet::harness;

fn main() {
    let (model, ds) = common::linear_model(Kind::Fashion);
    let test = ds.test.head(500);
    let rows = harness::bits_sweep(&model, &test, &[1, 2, 3, 4, 5, 6, 7, 8]);
    harness::print_bits_sweep("Fig 6: accuracy vs input bits (fashion corpus)", &rows);
    harness::write_csv(
        std::path::Path::new("results"),
        "fig6_fashion_bits.csv",
        &harness::bits_csv(&rows),
    )
    .ok();

    // figure-shape assertions (soft: print, don't panic, but flag)
    let full = rows.last().unwrap().ref_acc;
    let at3 = rows.iter().find(|r| r.bits == 3).unwrap().lut_acc;
    println!(
        "\nplateau check: 3-bit {:.1}% vs full-precision {:.1}% (paper: similar at 3 bits)",
        at3 * 100.0,
        full * 100.0
    );
}
