//! Fig. 4 — "Number of bits in input versus accuracy on MNIST data
//! using a linear classifier."
//!
//! Regenerates the figure's rows (accuracy per input bit-width for the
//! LUT engine, with the full-precision reference as the horizontal
//! line) and times one LUT inference per precision.

mod common;

use tablenet::data::synth::Kind;
use tablenet::engine::plan::{AffineMode, EnginePlan};
use tablenet::engine::Compiler;
use tablenet::harness::{self, bench::Bench};

fn main() {
    let (model, ds) = common::linear_model(Kind::Digits);
    let test = ds.test.head(500);

    let rows = harness::bits_sweep(&model, &test, &[1, 2, 3, 4, 5, 6, 7, 8]);
    harness::print_bits_sweep("Fig 4: accuracy vs input bits (digits corpus)", &rows);
    harness::write_csv(
        std::path::Path::new("results"),
        "fig4_mnist_bits.csv",
        &harness::bits_csv(&rows),
    )
    .ok();

    Bench::header("Fig 4 companion: one LUT inference per precision");
    let mut b = Bench::default();
    let img = test.image(0).to_vec();
    for bits in [1u32, 3, 8] {
        let plan = EnginePlan {
            affine: vec![AffineMode::BitplaneFixed { bits, m: 14, range_exp: 0 }],
            fallback: AffineMode::Float { planes: 11, m: 1 },
            r_o: 16,
        };
        let lut = Compiler::new(&model).plan(&plan).build().unwrap();
        b.run(&format!("lut_linear_infer bits={bits} m=14"), || {
            lut.infer(&img).class
        });
    }
}
