//! Fig. 8 — "Tradeoff between total LUT size versus number of
//! shift-and-add operations for inference on MNIST data using a CNN
//! classifier."
//!
//! LeNet geometry: conv 5x5x32, conv 5x5x64, fc 3136x1024, fc 1024x10.
//! Prints the configuration ladder (spatial blocks × float planes ×
//! dense whole-code variants), checks the in-text CNN numbers (12.49
//! MiB weights; ~400 MiB smallest all-bitplane config; 12.26 GiB-class
//! whole-code config), and measures a few engine inferences if
//! artifacts exist.

mod common;

use tablenet::data::synth::Kind;
use tablenet::engine::plan::EnginePlan;
use tablenet::engine::Compiler;
use tablenet::harness::{self, bench::Bench};
use tablenet::planner;
use tablenet::util::fmt_bits;

fn main() {
    let pts = planner::sweep::cnn_tradeoff();
    let mut rows: Vec<_> = pts
        .into_iter()
        .map(|point| harness::TradeoffRow {
            point,
            measured_acc: None,
            measured_evals: None,
            measured_ops: None,
        })
        .collect();
    harness::print_tradeoff("Fig 8: LUT size vs shift-and-add (LeNet CNN)", &mut rows);
    harness::write_csv(
        std::path::Path::new("results"),
        "fig8_cnn_tradeoff.csv",
        &harness::tradeoff_csv(&rows),
    )
    .ok();

    // in-text anchors
    let default_pt =
        planner::evaluate_plan(&planner::arch_geometry(tablenet::nn::Arch::Cnn), &EnginePlan::cnn_default());
    println!(
        "\npaper smallest-config anchor: {} (paper: 400 MiB), weights {} (paper 12.49 MiB)",
        fmt_bits(default_pt.size_bits),
        fmt_bits((3_273_504u64) * 32),
    );

    if let Some(model) = common::cnn_model() {
        let ds = common::dataset(Kind::Digits);
        let test = ds.test.head(8);
        let lut = Compiler::new(&model).plan(&EnginePlan::cnn_default()).build().unwrap();
        let (acc, ctr) = lut.accuracy(&test.images, 784, &test.labels);
        ctr.assert_multiplier_less();
        println!(
            "engine check over {} samples: {:.0}% accuracy, per-inference {ctr}",
            test.len(),
            acc * 100.0
        );
        Bench::header("Fig 8 companion: one CNN LUT inference");
        let mut b = Bench::new(
            std::env::var("TABLENET_BENCH_MS").ok().and_then(|v| v.parse().ok()).unwrap_or(3000),
        );
        let img = test.image(0).to_vec();
        b.run("cnn_lut_infer (4 layers)", || lut.infer(&img).class);
    } else {
        println!("(no artifacts/weights_cnn.bin — planner table only)");
    }
}
