//! Fig. 7 — "Tradeoff between total LUT size versus number of addition
//! operations for inference on MNIST data using a MLP classifier."
//!
//! Prints the configuration ladder (sorted by total LUT size, as the
//! paper's caption says), checks the in-text MLP numbers (2320 LUTs;
//! 162.6 MiB bitplaned vs 32.7 GiB whole-code; 14,652,918 vs 1,330,678
//! adds vs 1,332,224 reference MACs), and — when artifacts exist —
//! measures the engine on the real MLP.

mod common;

use tablenet::data::synth::Kind;
use tablenet::engine::plan::EnginePlan;
use tablenet::engine::Compiler;
use tablenet::harness::{self, bench::Bench};
use tablenet::planner;
use tablenet::util::{fmt_bits, fmt_ops};

fn main() {
    let pts = planner::sweep::mlp_tradeoff();

    // planner-only table first (covers the impractically-large configs)
    let (mut rows, measured): (Vec<_>, bool) = match common::mlp_model() {
        Some(model) => {
            let ds = common::dataset(Kind::Digits);
            let test = ds.test.head(100);
            (harness::tradeoff_rows(&model, &test, pts, 2), true)
        }
        None => (
            pts.into_iter()
                .map(|point| harness::TradeoffRow {
                    point,
                    measured_acc: None,
                    measured_evals: None,
                    measured_ops: None,
                })
                .collect(),
            false,
        ),
    };
    harness::print_tradeoff("Fig 7: LUT size vs additions (MLP)", &mut rows);
    harness::write_csv(
        std::path::Path::new("results"),
        "fig7_mlp_tradeoff.csv",
        &harness::tradeoff_csv(&rows),
    )
    .ok();

    // in-text checks
    let bitplaned = rows.iter().find(|r| r.point.ops == 14_652_918).expect("paper config");
    println!(
        "\npaper bitplaned config: {} LUTs, {} (paper: 2320 LUTs, 162.6 MiB)",
        bitplaned.point.num_luts,
        fmt_bits(bitplaned.point.size_bits)
    );
    let whole = rows.iter().find(|r| r.point.ops == 1_330_678).expect("whole-code config");
    println!(
        "paper whole-code config: {} (paper: 32.7 GiB) — {} adds vs {} MACs",
        fmt_bits(whole.point.size_bits),
        fmt_ops(whole.point.ops),
        fmt_ops(whole.point.ref_macs)
    );

    if measured {
        let model = common::mlp_model().unwrap();
        let ds = common::dataset(Kind::Digits);
        let img = ds.test.image(0).to_vec();
        Bench::header("Fig 7 companion: MLP engine inference");
        let mut b = Bench::default();
        let lut = Compiler::new(&model).plan(&EnginePlan::mlp_default()).build().unwrap();
        b.run("mlp_lut_infer (2320 LUTs, f16 planes)", || lut.infer(&img).class);
    }
}
