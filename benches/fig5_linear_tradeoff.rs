//! Fig. 5 — "Tradeoff between total LUT size versus number of
//! shift-and-add operations for inference on MNIST and Fashion MNIST
//! data using a linear classifier."
//!
//! Sweeps partitions of the 784-pixel input at 3-bit precision, prints
//! the size/ops frontier (including the paper's named 56-LUT/17.5 MiB
//! and 784-LUT/30.6 KiB points), measures accuracy on the engine for
//! materialisable configs, and times inference across chunk sizes.

mod common;

use tablenet::data::synth::Kind;
use tablenet::engine::plan::{AffineMode, EnginePlan};
use tablenet::engine::Compiler;
use tablenet::harness::{self, bench::Bench};
use tablenet::planner;

fn main() {
    let (model, ds) = common::linear_model(Kind::Digits);
    let test = ds.test.head(300);

    let pts = planner::sweep::linear_tradeoff(3);
    let mut rows = harness::tradeoff_rows(&model, &test, pts, 6);
    harness::print_tradeoff("Fig 5: LUT size vs shift-and-add (linear, 3-bit)", &mut rows);
    harness::write_csv(
        std::path::Path::new("results"),
        "fig5_linear_tradeoff.csv",
        &harness::tradeoff_csv(&rows),
    )
    .ok();

    // paper's named points must be present
    let named = rows
        .iter()
        .find(|r| r.point.num_luts == 56)
        .expect("56-LUT config in sweep");
    println!(
        "\npaper point: 56 LUTs -> {} (paper 17.5 MiB), {} evals (paper 168)",
        tablenet::util::fmt_bits(named.point.size_bits),
        named.point.lut_evals
    );

    Bench::header("Fig 5 companion: inference time vs chunk size");
    let mut b = Bench::default();
    let img = test.image(0).to_vec();
    for m in [1usize, 4, 14, 16] {
        let plan = EnginePlan {
            affine: vec![AffineMode::BitplaneFixed { bits: 3, m, range_exp: 0 }],
            fallback: AffineMode::Float { planes: 11, m: 1 },
            r_o: 16,
        };
        let lut = Compiler::new(&model).plan(&plan).build().unwrap();
        b.run(&format!("lut_linear_infer m={m}"), || lut.infer(&img).class);
    }
}
