//! Registry serving bench (the Layer-3 perf instrument): N named LUT
//! models behind one `ModelRegistry`, mixed concurrent load, a mid-run
//! hot-swap, and machine-readable `BENCH_serve.json` output (per-model
//! p50/p99 latency, req/s, mean batch size, plus fleet totals) so the
//! serving-path trajectory is tracked from PR to PR alongside
//! `BENCH_hotpath.json`.
//!
//!     cargo bench --bench serve_throughput -- [--requests 4000] \
//!         [--clients 4] [--models 3] [--max-batch 32]
//!
//! `TABLENET_BENCH_REQUESTS` overrides the request count (CI smoke).

mod common;

use std::sync::Arc;
use tablenet::config::cli::Args;
use tablenet::config::ServeConfig;
use tablenet::coordinator::registry::ModelRegistry;
use tablenet::data::synth::Kind;
use tablenet::engine::plan::{AffineMode, EnginePlan};
use tablenet::engine::Compiler;

use common::json_escape;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let n_requests = std::env::var("TABLENET_BENCH_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| args.get_usize("requests", 4000));
    let n_clients = args.get_usize("clients", 4).max(1);
    let n_models = args.get_usize("models", 3).clamp(1, 8);
    let cfg = ServeConfig {
        max_batch: args.get_usize("max-batch", 32),
        max_wait_us: args.get_u64("max-wait-us", 200),
        workers: args.get_usize("workers", 1),
        queue_cap: args.get_usize("queue-cap", 1024),
        ..ServeConfig::default()
    };

    let (model, ds) = common::linear_model(Kind::Digits);
    let plan_bits = |bits: u32| EnginePlan {
        affine: vec![AffineMode::BitplaneFixed { bits, m: 14, range_exp: 0 }],
        fallback: AffineMode::Float { planes: 11, m: 1 },
        r_o: 16,
    };

    // N tenants: the same trained weights compiled under distinct
    // plans, so each pipeline streams different table geometry
    let registry = ModelRegistry::new();
    let mut names = Vec::new();
    for i in 0..n_models {
        let bits = 2 + (i as u32 % 3);
        let engine =
            Compiler::new(&model).plan(&plan_bits(bits)).build().expect("plan materialises");
        let name = format!("m{i}_b{bits}");
        registry.register(&name, Arc::new(engine), &cfg).expect("unique names");
        names.push(name);
    }
    println!(
        "serve_throughput: {n_models} models, {n_clients} clients, {n_requests} requests, \
         max_batch {}",
        cfg.max_batch
    );

    let client_handle = registry.client();
    let names = Arc::new(names);
    let test = Arc::new(ds.test);
    let t0 = std::time::Instant::now();
    let mut joins = Vec::new();
    for c in 0..n_clients {
        let client = client_handle.clone();
        let names = names.clone();
        let test = test.clone();
        let per_client = n_requests / n_clients;
        joins.push(std::thread::spawn(move || {
            let mut served = 0usize;
            for i in 0..per_client {
                let k = c * per_client + i;
                let name = &names[k % names.len()];
                let idx = k % test.len();
                if client.infer(name, test.image(idx).to_vec()).is_ok() {
                    served += 1;
                }
            }
            served
        }));
    }

    // hot-swap tenant 0 mid-load: the bench doubles as a rolling-deploy
    // smoke under real traffic
    let planned = (n_requests / n_clients) * n_clients;
    while registry.fleet_completed() < (planned / 2) as u64 {
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    let v2 = Compiler::new(&model).plan(&plan_bits(4)).build().expect("v2 materialises");
    let swapped_version =
        registry.swap(&names[0], Arc::new(v2)).expect("swap succeeds under load");

    let served: usize = joins.into_iter().map(|j| j.join().unwrap()).sum();
    let wall = t0.elapsed().as_secs_f64();

    // ---- overload phase: shed-rate + p99 past capacity ----------------
    // One deliberately under-provisioned pipeline (1 worker, small
    // batches, tight queue, per-request deadline) hammered by 4x the
    // clients: requests that cannot make their deadline MUST shed with
    // a typed error, and the ones that are served report an honest p99.
    // The pipeline is retired before the fleet snapshot so the gated
    // per-model metrics above stay comparable across runs.
    let over_cfg = ServeConfig {
        max_batch: 4,
        max_wait_us: 100,
        workers: 1,
        queue_cap: 8,
        deadline_us: 1_500,
        degrade_after: 0,
        ..ServeConfig::default()
    };
    let over_engine =
        Compiler::new(&model).plan(&plan_bits(4)).build().expect("overload engine");
    registry.register("overload", Arc::new(over_engine), &over_cfg).expect("unique name");
    let over_requests = (n_requests / 2).max(400);
    let over_clients = (n_clients * 4).max(8);
    let t1 = std::time::Instant::now();
    let mut ojoins = Vec::new();
    for c in 0..over_clients {
        let client = client_handle.clone();
        let test = test.clone();
        let per = (over_requests / over_clients).max(1);
        ojoins.push(std::thread::spawn(move || {
            let (mut ok, mut shed) = (0usize, 0usize);
            for i in 0..per {
                let idx = (c * per + i) % test.len();
                match client.try_infer("overload", test.image(idx).to_vec()) {
                    Ok(_) => ok += 1,
                    Err(_) => shed += 1,
                }
            }
            (ok, shed)
        }));
    }
    let (mut over_ok, mut over_shed) = (0usize, 0usize);
    for j in ojoins {
        let (o, s) = j.join().unwrap();
        over_ok += o;
        over_shed += s;
    }
    let over_wall = t1.elapsed().as_secs_f64();
    let over_snap = registry.retire("overload").expect("retire overload pipeline");
    assert_eq!(over_snap.completed as usize, over_ok, "request lost under overload");
    assert_eq!(
        (over_snap.rejected + over_snap.deadline_shed) as usize,
        over_shed,
        "overload sheds must be typed and counted, never dropped"
    );
    let over_attempted = (over_ok + over_shed).max(1);
    let shed_rate = over_shed as f64 / over_attempted as f64;
    println!(
        "overload: {over_ok} ok, {over_shed} shed ({:.1}% of {over_attempted}) | \
         p99 {:.0}µs | {:.2}s",
        100.0 * shed_rate,
        over_snap.latency_p99_us,
        over_wall
    );

    let fleet = registry.shutdown();
    assert_eq!(fleet.completed() as usize, served, "request lost under bench load");
    fleet.assert_multiplier_less();

    println!("{fleet}");
    let total_rps = served as f64 / wall;
    println!(
        "wall {wall:.2}s -> {total_rps:.0} req/s | swapped '{}' to v{swapped_version} mid-run",
        names[0]
    );

    // ---- machine-readable output: BENCH_serve.json --------------------
    let mut json = String::new();
    json.push_str("{\n  \"bench\": \"serve_throughput\",\n");
    json.push_str(&format!(
        "  \"config\": {{\"models\": {n_models}, \"clients\": {n_clients}, \
         \"requests\": {n_requests}, \"max_batch\": {}, \"workers\": {}}},\n",
        cfg.max_batch, cfg.workers
    ));
    json.push_str("  \"models\": [\n");
    let entries: Vec<String> = fleet
        .models
        .iter()
        .map(|(name, m)| {
            format!(
                "    {{\"name\": \"{}\", \"version\": {}, \"completed\": {}, \
                 \"p50_us\": {:.1}, \"p99_us\": {:.1}, \"rps\": {:.1}, \
                 \"mean_batch\": {:.2}, \"mults\": {}}}",
                json_escape(name),
                m.version,
                m.stats.completed,
                m.stats.latency_p50_us,
                m.stats.latency_p99_us,
                m.stats.throughput_rps,
                m.stats.mean_batch,
                m.stats.ops.mults
            )
        })
        .collect();
    json.push_str(&entries.join(",\n"));
    json.push_str("\n  ],\n");
    json.push_str(&format!(
        "  \"overload\": {{\"requests\": {over_attempted}, \"ok\": {over_ok}, \
         \"shed\": {over_shed}, \"shed_rate\": {shed_rate:.4}, \
         \"p99_us\": {:.1}, \"wall_s\": {over_wall:.3}}},\n",
        over_snap.latency_p99_us
    ));
    json.push_str(&format!("  \"total_rps\": {total_rps:.1},\n"));
    json.push_str(&format!("  \"wall_s\": {wall:.3},\n"));
    json.push_str(&format!("  \"swapped_model_version\": {swapped_version}\n"));
    json.push_str("}\n");
    std::fs::write("BENCH_serve.json", &json).expect("write BENCH_serve.json");
    println!("wrote BENCH_serve.json");
}
