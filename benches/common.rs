//! Shared helpers for the figure benches: artifact loading with
//! in-Rust training fallback, dataset access.
#![allow(dead_code)]

use std::path::Path;
use tablenet::data::synth::Kind;
use tablenet::data::{load_or_generate, Dataset};
use tablenet::nn::{weights, Arch, Model};
use tablenet::train::{train_dense, TrainConfig};

/// Escape a string for embedding in the BENCH_*.json outputs.
pub fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

pub fn dataset(kind: Kind) -> Dataset {
    load_or_generate(Path::new("data/synth"), kind, 6000, 1000, 7)
        .expect("dataset generates")
}

/// Linear model: artifact if present, otherwise a quick in-Rust train.
pub fn linear_model(kind: Kind) -> (Model, Dataset) {
    let ds = dataset(kind);
    let path = match kind {
        Kind::Digits => "artifacts/weights_linear.bin",
        Kind::Fashion => "artifacts/weights_linear_fashion.bin",
    };
    let model = weights::load_model(Arch::Linear, Path::new(path)).unwrap_or_else(|_| {
        eprintln!("[bench] {path} missing; training in-Rust");
        train_dense(
            &ds.train,
            &[784, 10],
            &TrainConfig { steps: 2000, lr: 0.2, input_bits: Some(3), ..Default::default() },
        )
    });
    (model, ds)
}

/// MLP model from artifacts (falls back to a quick small-width train so
/// the bench still runs standalone — costs are computed from the paper
/// geometry either way).
pub fn mlp_model() -> Option<Model> {
    weights::load_model(Arch::Mlp, Path::new("artifacts/weights_mlp.bin")).ok()
}

pub fn cnn_model() -> Option<Model> {
    weights::load_model(Arch::Cnn, Path::new("artifacts/weights_cnn.bin")).ok()
}
