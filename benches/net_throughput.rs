//! Socket-tier soak bench (the Layer-4 perf instrument): a real LUT
//! model served by [`NetServer`] over loopback TCP, driven by blocking
//! wire clients at several connection counts, with a mid-soak
//! quarantined swap and deterministic fault injection. Emits
//! machine-readable `BENCH_net.json` (per-phase rows/s and frame-RTT
//! p50/p99) so the network-path trajectory is tracked from PR to PR
//! alongside `BENCH_serve.json`.
//!
//!     cargo bench --bench net_throughput -- [--requests 1000000] \
//!         [--rows-per-frame 16] [--net-threads 0] [--admission-budget 0]
//!
//! `TABLENET_BENCH_REQUESTS` overrides the row count (CI smoke). The
//! bench asserts the full wire accounting invariant: every row sent is
//! answered exactly once (served or typed-shed), and the server-side
//! ledger balances to zero.

mod common;

#[cfg(not(unix))]
fn main() {
    println!("net_throughput: the socket tier is unix-only (epoll/kqueue); skipping");
}

#[cfg(unix)]
fn main() {
    use std::sync::Arc;
    use std::time::{Duration, Instant};
    use tablenet::config::cli::Args;
    use tablenet::config::ServeConfig;
    use tablenet::coordinator::faults::{silence_injected_panics, FaultInjector, FaultPlan};
    use tablenet::coordinator::registry::ModelRegistry;
    use tablenet::data::synth::Kind;
    use tablenet::engine::plan::{AffineMode, EnginePlan};
    use tablenet::engine::Compiler;
    use tablenet::net::{
        AdmissionController, Frame, NetClient, NetServer, NetServerOptions, Status,
    };
    use tablenet::util::percentile;

    silence_injected_panics();
    let args = Args::parse(std::env::args().skip(1));
    let n_rows = std::env::var("TABLENET_BENCH_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| args.get_usize("requests", 1_000_000));
    let rows_per_frame = args.get_usize("rows-per-frame", 16).clamp(1, 4096);
    let net_threads = args.get_usize("net-threads", 0);
    let budget = args.get_u64("admission-budget", 0);
    const FEATURES: u32 = 784;
    // two connection counts so BENCH_net.json tracks scaling, not just
    // a single operating point
    let phase_conns = [2usize, 8usize];

    // deterministic chaos: rare injected panics and latency spikes keep
    // the soak honest — sheds must surface as typed verdicts, never as
    // lost rows
    let plan = FaultPlan::parse("seed=7,latency_prob=0.02,latency_us=200,panic_prob=0.01")
        .expect("fault plan parses");
    let registry = ModelRegistry::with_faults(Arc::new(FaultInjector::new(plan)));
    let cfg = ServeConfig {
        max_batch: 32,
        max_wait_us: 200,
        workers: 2,
        queue_cap: 1024,
        ..ServeConfig::default()
    };
    let plan_bits = |bits: u32| EnginePlan {
        affine: vec![AffineMode::BitplaneFixed { bits, m: 14, range_exp: 0 }],
        fallback: AffineMode::Float { planes: 11, m: 1 },
        r_o: 16,
    };
    let (model, ds) = common::linear_model(Kind::Digits);
    let engine =
        Compiler::new(&model).plan(&plan_bits(3)).build().expect("plan materialises");
    registry.register("digits", Arc::new(engine), &cfg).expect("unique name");

    let admission = Arc::new(AdmissionController::new(budget));
    let server = NetServer::start(
        "127.0.0.1:0",
        registry.client(),
        admission,
        NetServerOptions { threads: net_threads, ..NetServerOptions::default() },
    )
    .expect("server binds loopback");
    let addr = server.local_addr().to_string();
    println!(
        "net_throughput: {n_rows} rows, frames of {rows_per_frame}, {} net threads, \
         phases at {phase_conns:?} connections",
        server.threads()
    );

    let test = Arc::new(ds.test);
    struct Phase {
        connections: usize,
        rows: u64,
        ok: u64,
        shed: u64,
        rps: f64,
        p50_us: f64,
        p99_us: f64,
        wall_s: f64,
    }
    let mut phases: Vec<Phase> = Vec::new();
    let mut swapped_version = 0u64;

    for (pi, &conns) in phase_conns.iter().enumerate() {
        let phase_rows = n_rows / phase_conns.len();
        let t0 = Instant::now();
        let mut joins = Vec::new();
        for c in 0..conns {
            let share = phase_rows / conns + usize::from(c < phase_rows % conns);
            let addr = addr.clone();
            let test = test.clone();
            joins.push(std::thread::spawn(move || {
                let mut cl = NetClient::connect_retry(&addr, 5_000).expect("connect");
                cl.set_read_timeout(Some(Duration::from_secs(120))).expect("timeout");
                let (mut ok, mut shed) = (0u64, 0u64);
                let mut rtts: Vec<f64> = Vec::new();
                let mut data: Vec<f32> =
                    Vec::with_capacity(rows_per_frame * FEATURES as usize);
                let mut left = share;
                let mut k = c;
                while left > 0 {
                    let n = left.min(rows_per_frame);
                    data.clear();
                    for r in 0..n {
                        data.extend_from_slice(test.image((k + r) % test.len()));
                    }
                    k = (k + n) % test.len();
                    let t = Instant::now();
                    match cl.infer("digits", FEATURES, &data).expect("frame answered") {
                        Frame::Reply(rep) => {
                            assert_eq!(rep.rows.len(), n, "row lost on the wire");
                            for row in &rep.rows {
                                if row.status == Status::Ok {
                                    ok += 1;
                                } else {
                                    shed += 1;
                                }
                            }
                        }
                        Frame::Error(e) => {
                            assert!(
                                e.status.is_queue_full_class(),
                                "unexpected frame-level error: {e:?}"
                            );
                            shed += n as u64;
                        }
                        other => panic!("unexpected frame: {other:?}"),
                    }
                    rtts.push(t.elapsed().as_secs_f64() * 1e6);
                    left -= n;
                }
                (ok, shed, rtts)
            }));
        }

        // quarantined swap at roughly half of the first phase, under
        // full socket load — the soak doubles as a rolling-deploy smoke
        if pi == 0 {
            let target = (phase_rows / 2) as u64;
            let t = Instant::now();
            while server.rows_done() < target {
                assert!(
                    t.elapsed() < Duration::from_secs(600),
                    "soak stalled before the mid-run swap"
                );
                std::thread::sleep(Duration::from_millis(1));
            }
            let v2 =
                Compiler::new(&model).plan(&plan_bits(4)).build().expect("v2 materialises");
            swapped_version =
                registry.swap_quarantined("digits", Arc::new(v2)).expect("swap under load");
        }

        let (mut ok, mut shed) = (0u64, 0u64);
        let mut rtts: Vec<f64> = Vec::new();
        for j in joins {
            let (o, s, r) = j.join().expect("client thread");
            ok += o;
            shed += s;
            rtts.extend(r);
        }
        let wall = t0.elapsed().as_secs_f64();
        assert_eq!(
            ok + shed,
            phase_rows as u64,
            "phase {pi}: rows sent != rows answered (zero-lost violated)"
        );
        let rps = phase_rows as f64 / wall.max(1e-9);
        let (p50, p99) = (percentile(&rtts, 50.0), percentile(&rtts, 99.0));
        println!(
            "phase {pi}: {conns} connections | {phase_rows} rows in {wall:.2}s -> \
             {rps:.0} rows/s | frame RTT p50 {p50:.0}µs p99 {p99:.0}µs | {ok} ok, {shed} shed"
        );
        phases.push(Phase {
            connections: conns,
            rows: phase_rows as u64,
            ok,
            shed,
            rps,
            p50_us: p50,
            p99_us: p99,
            wall_s: wall,
        });
    }

    // the server-side ledger must balance to zero and agree with the
    // client-side totals exactly
    let reactor_threads = server.threads();
    let snap = server.shutdown();
    snap.assert_accounted();
    let total_rows: u64 = phases.iter().map(|p| p.rows).sum();
    let total_ok: u64 = phases.iter().map(|p| p.ok).sum();
    assert_eq!(snap.rows_done, total_rows, "wire ledger disagrees with rows sent");
    assert_eq!(snap.rows_ok(), total_ok, "wire ledger disagrees with Ok verdicts");
    assert_eq!(snap.admission.in_flight, 0, "admission tokens leaked");
    let fleet = registry.shutdown();
    fleet.assert_multiplier_less();

    let total_wall: f64 = phases.iter().map(|p| p.wall_s).sum();
    let total_rps = total_rows as f64 / total_wall.max(1e-9);
    println!(
        "total: {total_rows} rows in {total_wall:.2}s -> {total_rps:.0} rows/s | \
         swapped 'digits' to v{swapped_version} mid-soak | accounting exact"
    );

    // ---- machine-readable output: BENCH_net.json ----------------------
    let mut json = String::new();
    json.push_str("{\n  \"bench\": \"net_throughput\",\n");
    json.push_str(&format!(
        "  \"config\": {{\"requests\": {n_rows}, \"rows_per_frame\": {rows_per_frame}, \
         \"net_threads\": {reactor_threads}, \"features\": {FEATURES}, \
         \"admission_budget\": {budget}}},\n"
    ));
    json.push_str("  \"phases\": [\n");
    let entries: Vec<String> = phases
        .iter()
        .map(|p| {
            format!(
                "    {{\"connections\": {}, \"rows\": {}, \"ok\": {}, \"shed\": {}, \
                 \"rps\": {:.1}, \"p50_us\": {:.1}, \"p99_us\": {:.1}, \"wall_s\": {:.3}}}",
                p.connections, p.rows, p.ok, p.shed, p.rps, p.p50_us, p.p99_us, p.wall_s
            )
        })
        .collect();
    json.push_str(&entries.join(",\n"));
    json.push_str("\n  ],\n");
    json.push_str(&format!("  \"total_rows\": {total_rows},\n"));
    json.push_str(&format!("  \"total_rps\": {total_rps:.1},\n"));
    json.push_str(&format!("  \"swapped_model_version\": {swapped_version}\n"));
    json.push_str("}\n");
    std::fs::write("BENCH_net.json", &json).expect("write BENCH_net.json");
    println!("wrote BENCH_net.json");
}
