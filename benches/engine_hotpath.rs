//! Hot-path microbenches (the §Perf instrument): LUT bank evaluation vs
//! the multiply-full reference, batched table-stationary evaluation vs
//! the per-sample path, layer-boundary encodes, coordinator round-trip.
//!
//! This is the bench the performance pass iterates on. Alongside the
//! human-readable table it emits machine-readable `BENCH_hotpath.json`
//! so the perf trajectory is tracked from PR to PR. The "seed batch=1
//! path" case reconstructs the pre-arena implementation (boxed
//! `Vec<Vec<i64>>` tables, n-pass plane-index deposit, per-call
//! allocation) as the before/after baseline for the batched engine.

mod common;

use std::sync::Arc;
use std::time::Instant;
use tablenet::config::ServeConfig;
use tablenet::coordinator::Coordinator;
use tablenet::data::synth::Kind;
use tablenet::engine::counters::Counters;
use tablenet::engine::f16enc::acc_vec_to_f16;
use tablenet::engine::plan::EnginePlan;
use tablenet::engine::scratch::Scratch;
use tablenet::engine::Compiler;
use tablenet::harness::bench::{Bench, BenchResult};
use tablenet::lut::bitplane::DenseBitplaneLut;
use tablenet::lut::dense::DenseWholeLut;
use tablenet::lut::floatplane::{DenseFloatLut, FloatLutConfig};
use tablenet::lut::kernel;
use tablenet::lut::{Partition, ACC_FRAC};
use tablenet::nn::Model;
use tablenet::quant::f16::F16;
use tablenet::quant::FixedFormat;
use tablenet::tensor::ops::matmul;
use tablenet::tensor::Tensor;
use tablenet::util::Rng;

/// Faithful reconstruction of the seed's bitplane bank: one boxed
/// `Vec<i64>` per chunk and the pre-refactor per-sample inner loop
/// (n-pass-free index build but no packing, i64 rows, fresh accumulator
/// allocation per call). Kept here as the perf baseline the batched
/// arena engine is measured against.
struct SeedBitplane {
    chunks: Vec<Vec<usize>>,
    tables: Vec<Vec<i64>>,
    bias_acc: Vec<i64>,
    p: usize,
    q: usize,
    bits: u32,
}

impl SeedBitplane {
    fn build(w: &[f32], b: &[f32], p: usize, q: usize, m: usize, bits: u32) -> SeedBitplane {
        let to_acc = |v: f64| (v * (1u64 << ACC_FRAC) as f64).round() as i64;
        let part = Partition::contiguous(q, m);
        let mut tables = Vec::new();
        for chunk in &part.chunks {
            let rows = 1usize << chunk.len();
            let mut table = vec![0i64; rows * p];
            for idx in 0..rows {
                for (e, &col) in chunk.iter().enumerate() {
                    if (idx >> e) & 1 == 1 {
                        let scale = (-(bits as f64)).exp2();
                        for o in 0..p {
                            table[idx * p + o] += to_acc(w[o * q + col] as f64 * scale);
                        }
                    }
                }
            }
            tables.push(table);
        }
        let bias_acc = b.iter().map(|&v| to_acc(v as f64)).collect();
        SeedBitplane { chunks: part.chunks, tables, bias_acc, p, q, bits }
    }

    fn eval_codes(&self, codes: &[u32], ctr: &mut Counters) -> Vec<i64> {
        assert_eq!(codes.len(), self.q);
        let n = self.bits as usize;
        let mut acc = self.bias_acc.clone();
        ctr.adds += self.p as u64;
        let mut idx = [0usize; 16];
        for (c, chunk) in self.chunks.iter().enumerate() {
            let table = &self.tables[c];
            idx[..n].fill(0);
            for (e, &col) in chunk.iter().enumerate() {
                let code = codes[col] as usize;
                for (j, slot) in idx[..n].iter_mut().enumerate() {
                    *slot |= ((code >> j) & 1) << e;
                }
            }
            ctr.lut_evals += n as u64;
            for (j, &row_idx) in idx[..n].iter().enumerate() {
                if row_idx == 0 {
                    continue;
                }
                let row = &table[row_idx * self.p..(row_idx + 1) * self.p];
                for (a, &r) in acc.iter_mut().zip(row) {
                    *a += r << j;
                }
                ctr.shift_adds += self.p as u64;
            }
        }
        acc
    }
}

/// samples/sec for a recorded case that evaluates `n` samples per
/// closure invocation.
fn samples_per_sec(r: &BenchResult, n: usize) -> f64 {
    if r.mean_ns > 0.0 {
        n as f64 * 1e9 / r.mean_ns
    } else {
        0.0
    }
}

use common::json_escape;

fn main() {
    let mut rng = Rng::new(1);
    let (p, q) = (10usize, 784usize);
    let w: Vec<f32> = (0..p * q).map(|_| rng.normal() * 0.1).collect();
    let b: Vec<f32> = (0..p).map(|_| rng.normal() * 0.02).collect();
    let x: Vec<f32> = (0..q).map(|_| rng.f32()).collect();

    Bench::header("dense affine 784->10: LUT banks vs reference matmul");
    let mut bench = Bench::default();
    // name -> samples evaluated per closure call (for samples/sec)
    let mut case_samples: Vec<(String, usize)> = Vec::new();
    fn track(name: &str, n: usize, cs: &mut Vec<(String, usize)>) {
        cs.push((name.to_string(), n));
    }

    let wt = Tensor::new(&[q, p], {
        // transpose for the reference x@W^T layout
        let mut t = vec![0f32; p * q];
        for o in 0..p {
            for i in 0..q {
                t[i * p + o] = w[o * q + i];
            }
        }
        t
    });
    let xt = Tensor::new(&[1, q], x.clone());
    track("reference matmul f32 (7840 MACs)", 1, &mut case_samples);
    bench.run("reference matmul f32 (7840 MACs)", || {
        matmul(&xt, &wt).data()[0]
    });

    let plane14 = DenseBitplaneLut::build(
        &w, &b, p, q, Partition::contiguous(q, 14), FixedFormat::new(3),
    )
    .unwrap();
    track("bitplane LUT m=14 r=3 (56 tables)", 1, &mut case_samples);
    bench.run("bitplane LUT m=14 r=3 (56 tables)", || {
        let mut c = Counters::default();
        plane14.eval_f32(&x, &mut c)[0]
    });

    let plane1 = DenseBitplaneLut::build(
        &w, &b, p, q, Partition::contiguous(q, 1), FixedFormat::new(3),
    )
    .unwrap();
    track("bitplane LUT m=1 r=3 (784 tables)", 1, &mut case_samples);
    bench.run("bitplane LUT m=1 r=3 (784 tables)", || {
        let mut c = Counters::default();
        plane1.eval_f32(&x, &mut c)[0]
    });

    let whole2 = DenseWholeLut::build(
        &w, &b, p, q, Partition::contiguous(q, 2), FixedFormat::new(3),
    )
    .unwrap();
    track("whole-code LUT m=2 r=3 (392 tables)", 1, &mut case_samples);
    bench.run("whole-code LUT m=2 r=3 (392 tables)", || {
        let mut c = Counters::default();
        whole2.eval_f32(&x, &mut c)[0]
    });

    let fl = DenseFloatLut::build(
        &w, &b, p, q, Partition::singletons(q), FloatLutConfig::default(),
    )
    .unwrap();
    track("float16-plane LUT m=1 (784 tables)", 1, &mut case_samples);
    bench.run("float16-plane LUT m=1 (784 tables)", || {
        let mut c = Counters::default();
        fl.eval_f32(&x, &mut c)[0]
    });

    // quantized-input variants (hot path once input codes are ready)
    let fmt3 = FixedFormat::new(3);
    let codes: Vec<u32> = x.iter().map(|&v| fmt3.quantize(v)).collect();
    track("bitplane LUT m=14 from codes", 1, &mut case_samples);
    bench.run("bitplane LUT m=14 from codes", || {
        let mut c = Counters::default();
        plane14.eval_codes(&codes, &mut c)[0]
    });

    // ---- batched table-stationary evaluation --------------------------
    Bench::header("batched table-stationary eval (784->10, m=14, r=3)");
    let nsamp = 128usize;
    let xs: Vec<f32> = (0..nsamp * q).map(|_| rng.f32()).collect();
    let codes_all: Vec<u32> = xs.iter().map(|&v| fmt3.quantize(v)).collect();

    // the seed's batch=1 path: boxed i64 tables, per-sample eval with a
    // fresh accumulator per call — what serving executed before this PR
    let seed = SeedBitplane::build(&w, &b, p, q, 14, 3);
    {
        // sanity: the seed reconstruction and the arena bank agree
        let mut c1 = Counters::default();
        let mut c2 = Counters::default();
        let a = seed.eval_codes(&codes, &mut c1);
        let bnew = plane14.eval_codes(&codes, &mut c2);
        assert_eq!(a, bnew, "seed baseline diverged from arena bank");
    }
    track("seed batch=1 path (32 samples, boxed i64)", 32, &mut case_samples);
    bench.run("seed batch=1 path (32 samples, boxed i64)", || {
        let mut c = Counters::default();
        let mut sum = 0i64;
        for s in 0..32 {
            sum += seed.eval_codes(&codes_all[s * q..(s + 1) * q], &mut c)[0];
        }
        sum
    });

    track("arena per-sample eval_codes (32 samples)", 32, &mut case_samples);
    bench.run("arena per-sample eval_codes (32 samples)", || {
        let mut c = Counters::default();
        let mut sum = 0i64;
        for s in 0..32 {
            sum += plane14.eval_codes(&codes_all[s * q..(s + 1) * q], &mut c)[0];
        }
        sum
    });

    let mut out = vec![0i64; nsamp * p];
    let mut batch_ctrs = vec![Counters::default(); nsamp];
    for &bsz in &[1usize, 8, 32, 128] {
        let name = format!("bitplane eval_batch batch={bsz}");
        track(&name, bsz, &mut case_samples);
        bench.run(&name, || {
            plane14.eval_batch(
                &codes_all[..bsz * q],
                bsz,
                &mut out[..bsz * p],
                &mut batch_ctrs[..bsz],
            );
            out[0]
        });
    }

    track("whole-code eval_batch batch=32", 32, &mut case_samples);
    bench.run("whole-code eval_batch batch=32", || {
        whole2.eval_batch(&codes_all[..32 * q], 32, &mut out[..32 * p], &mut batch_ctrs[..32]);
        out[0]
    });

    let halves: Vec<F16> = xs.iter().map(|&v| F16::from_f32(v.max(0.0))).collect();
    track("float16-plane eval_batch batch=32", 32, &mut case_samples);
    bench.run("float16-plane eval_batch batch=32", || {
        fl.eval_batch_f16(&halves[..32 * q], 32, &mut out[..32 * p], &mut batch_ctrs[..32]);
        out[0]
    });

    // ---- forced-kernel A/B: the same banks under each kernel ----------
    // (kernel:* cases are tracked-not-gated by tools/bench_compare.py —
    // the per-host speedup is informative, not a regression gate)
    Bench::header("kernel dispatch A/B: forced scalar vs avx2 (batch=32)");
    let kernels: &[kernel::Kernel] = if kernel::avx2_available() {
        &[kernel::Kernel::Scalar, kernel::Kernel::Avx2]
    } else {
        println!("cpu lacks AVX2 — recording scalar-only kernel cases");
        &[kernel::Kernel::Scalar]
    };
    for &kern in kernels {
        let guard = kernel::force(kern);
        let name = format!("kernel:{} bitplane eval_batch batch=32", kern.name());
        track(&name, 32, &mut case_samples);
        bench.run(&name, || {
            plane14.eval_batch(
                &codes_all[..32 * q],
                32,
                &mut out[..32 * p],
                &mut batch_ctrs[..32],
            );
            out[0]
        });
        let name = format!("kernel:{} whole-code eval_batch batch=32", kern.name());
        track(&name, 32, &mut case_samples);
        bench.run(&name, || {
            whole2.eval_batch(
                &codes_all[..32 * q],
                32,
                &mut out[..32 * p],
                &mut batch_ctrs[..32],
            );
            out[0]
        });
        let name = format!("kernel:{} float16-plane eval_batch batch=32", kern.name());
        track(&name, 32, &mut case_samples);
        bench.run(&name, || {
            fl.eval_batch_f16(
                &halves[..32 * q],
                32,
                &mut out[..32 * p],
                &mut batch_ctrs[..32],
            );
            out[0]
        });
        drop(guard);
    }

    // ---- stage folding A/B: fused epilogues vs naive lowering ---------
    // (fusion:* cases are tracked-not-gated by tools/bench_compare.py:
    // the fused-plan hotpath metric lands as informative first and gets
    // ratcheted into the gate once a baseline exists)
    Bench::header("stage folding A/B: fused vs unfused MLP pipeline (batch=32)");
    let mlp = Model::mlp(vec![
        (Tensor::randn(&[32, 784], 0.05, &mut rng), Tensor::zeros(&[32])),
        (Tensor::randn(&[16, 32], 0.2, &mut rng), Tensor::zeros(&[16])),
        (Tensor::randn(&[10, 16], 0.3, &mut rng), Tensor::zeros(&[10])),
    ]);
    let fused_mlp = Compiler::new(&mlp).plan(&EnginePlan::mlp_default()).build().unwrap();
    let unfused_mlp = Compiler::new(&mlp)
        .plan(&EnginePlan::mlp_default())
        .fuse(false)
        .build()
        .unwrap();
    let mlp_imgs: Vec<f32> = (0..32 * q).map(|_| rng.f32()).collect();
    let mut fused_scratch = Scratch::new();
    track("fusion:fused mlp infer_batch (batch=32)", 32, &mut case_samples);
    bench.run("fusion:fused mlp infer_batch (batch=32)", || {
        fused_mlp.infer_batch(&mlp_imgs, 32, &mut fused_scratch).classes[0]
    });
    let mut unfused_scratch = Scratch::new();
    track("fusion:unfused mlp infer_batch (batch=32)", 32, &mut case_samples);
    bench.run("fusion:unfused mlp infer_batch (batch=32)", || {
        unfused_mlp.infer_batch(&mlp_imgs, 32, &mut unfused_scratch).classes[0]
    });

    Bench::header("layer-boundary encode");
    let accs: Vec<i64> = (0..1024).map(|_| (rng.next_u64() >> 20) as i64).collect();
    track("acc -> f16 encode x1024", 1, &mut case_samples);
    bench.run("acc -> f16 encode x1024", || {
        let mut c = Counters::default();
        acc_vec_to_f16(&accs, 32, &mut c).len()
    });

    Bench::header("end-to-end: engine infer + coordinator round-trip");
    let (model, ds) = common::linear_model(Kind::Digits);
    let engine = Compiler::new(&model).plan(&EnginePlan::linear_default()).build().unwrap();
    let img = ds.test.image(0).to_vec();
    track("linear engine infer (end-to-end)", 1, &mut case_samples);
    bench.run("linear engine infer (end-to-end)", || {
        engine.infer(&img).class
    });

    // batched end-to-end on 32 distinct test images
    let batch_imgs: Vec<f32> = (0..32)
        .flat_map(|i| ds.test.image(i % ds.test.len()).to_vec())
        .collect();
    let mut scratch = Scratch::new();
    track("linear engine infer_batch (batch=32)", 32, &mut case_samples);
    bench.run("linear engine infer_batch (batch=32)", || {
        engine.infer_batch(&batch_imgs, 32, &mut scratch).classes[0]
    });

    let coord = Coordinator::start(
        Arc::new(Compiler::new(&model).plan(&EnginePlan::linear_default()).build().unwrap()),
        &ServeConfig {
            max_batch: 1,
            max_wait_us: 1,
            workers: 1,
            queue_cap: 64,
            ..ServeConfig::default()
        },
    );
    let client = coord.client();
    track("coordinator round-trip (batch=1)", 1, &mut case_samples);
    bench.run("coordinator round-trip (batch=1)", || {
        client.infer_blocking(img.clone()).unwrap().class
    });
    drop(client);
    coord.shutdown();

    // coordinator throughput with real dynamic batching (max_batch=32,
    // 4 concurrent clients) — measured manually, not via Bench
    let n_requests = 2000usize;
    let coord = Coordinator::start(
        Arc::new(Compiler::new(&model).plan(&EnginePlan::linear_default()).build().unwrap()),
        &ServeConfig {
            max_batch: 32,
            max_wait_us: 200,
            workers: 1,
            queue_cap: 1024,
            ..ServeConfig::default()
        },
    );
    let test = Arc::new(ds.test);
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for c in 0..4usize {
        let client = coord.client();
        let test = test.clone();
        joins.push(std::thread::spawn(move || {
            for i in 0..n_requests / 4 {
                let idx = (c * 97 + i) % test.len();
                let _ = client.infer_blocking(test.image(idx).to_vec()).unwrap();
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let coord_rps = n_requests as f64 / elapsed;
    let snap = coord.shutdown();
    println!(
        "\ncoordinator throughput (max_batch=32, 4 clients): {coord_rps:.0} req/s, \
         mean batch {:.1}",
        snap.mean_batch
    );

    if let Some(ratio) = bench.ratio(
        "bitplane LUT m=14 r=3 (56 tables)",
        "reference matmul f32 (7840 MACs)",
    ) {
        println!("\nLUT(m=14) / reference-matmul time ratio: {ratio:.2}x");
    }

    // headline acceptance ratio: batched arena eval vs the seed's
    // batch=1 path, in samples/sec
    let find = |name: &str| bench.results().iter().find(|r| r.name == name);
    let speedup = match (
        find("bitplane eval_batch batch=32"),
        find("seed batch=1 path (32 samples, boxed i64)"),
    ) {
        (Some(b32), Some(b1)) => {
            let s = samples_per_sec(b32, 32) / samples_per_sec(b1, 32).max(1e-9);
            println!(
                "batched speedup (batch=32 vs seed batch=1 path): {s:.2}x samples/sec"
            );
            Some(s)
        }
        _ => None,
    };

    // ---- per-bank tables/sec + kernel A/B speedups --------------------
    // tables-per-sample is measured from the bank's own counters (one
    // batch=1 eval), not hand-derived, so the rate stays honest if a
    // bank's lookup accounting ever changes
    let tables_per_sample = {
        let one = |f: &mut dyn FnMut(&mut Counters)| {
            let mut c = Counters::default();
            f(&mut c);
            c.lut_evals as f64
        };
        [
            ("bitplane_m14", "bitplane eval_batch batch=32", one(&mut |c| {
                plane14.eval_batch(&codes_all[..q], 1, &mut out[..p], std::slice::from_mut(c));
            })),
            ("whole_m2", "whole-code eval_batch batch=32", one(&mut |c| {
                whole2.eval_batch(&codes_all[..q], 1, &mut out[..p], std::slice::from_mut(c));
            })),
            ("float_m1", "float16-plane eval_batch batch=32", one(&mut |c| {
                fl.eval_batch_f16(&halves[..q], 1, &mut out[..p], std::slice::from_mut(c));
            })),
        ]
    };
    let bank_rates: Vec<(&str, f64)> = tables_per_sample
        .iter()
        .map(|&(bank, case, tps)| {
            let rate = find(case).map(|r| samples_per_sec(r, 32) * tps).unwrap_or(0.0);
            (bank, rate)
        })
        .collect();
    println!("\nper-bank table-lookup throughput (kernel: {}):", kernel::active().name());
    for (bank, rate) in &bank_rates {
        println!("  {bank:<14} {:.0} tables/sec", rate);
    }

    // fused-vs-unfused pipeline speedup (fewer ActBuf sweeps; the op
    // stream itself is identical, so this measures the deleted stage
    // boundaries)
    let fusion_speedup = match (
        find("fusion:fused mlp infer_batch (batch=32)"),
        find("fusion:unfused mlp infer_batch (batch=32)"),
    ) {
        (Some(f), Some(u)) => {
            let s = samples_per_sec(f, 32) / samples_per_sec(u, 32).max(1e-9);
            println!(
                "fusion speedup (fused {} stages vs unfused {}): {s:.2}x samples/sec",
                fused_mlp.num_stages(),
                unfused_mlp.num_stages()
            );
            Some(s)
        }
        _ => None,
    };

    let kernel_pair = |case: &str| -> Option<f64> {
        let s = find(&format!("kernel:scalar {case}"))?;
        let v = find(&format!("kernel:avx2 {case}"))?;
        Some(samples_per_sec(v, 32) / samples_per_sec(s, 32).max(1e-9))
    };
    let kernel_speedups: Vec<(&str, Option<f64>)> = vec![
        ("bitplane", kernel_pair("bitplane eval_batch batch=32")),
        ("whole", kernel_pair("whole-code eval_batch batch=32")),
        ("float", kernel_pair("float16-plane eval_batch batch=32")),
    ];
    if kernel_speedups.iter().any(|(_, s)| s.is_some()) {
        let line = kernel_speedups
            .iter()
            .filter_map(|(b, s)| s.map(|s| format!("{b} {s:.2}x")))
            .collect::<Vec<_>>()
            .join(", ");
        println!("kernel speedup (avx2 vs scalar, batch=32): {line}");
    }

    // ---- machine-readable output: BENCH_hotpath.json ------------------
    let mut json = String::new();
    json.push_str("{\n  \"bench\": \"engine_hotpath\",\n");
    json.push_str("  \"config\": {\"p\": 10, \"q\": 784, \"m\": 14, \"bits\": 3},\n");
    json.push_str(&format!("  \"kernel\": \"{}\",\n", kernel::active().name()));
    json.push_str("  \"cases\": [\n");
    let results = bench.results();
    for (i, r) in results.iter().enumerate() {
        let n = case_samples
            .iter()
            .find(|(name, _)| name == &r.name)
            .map(|(_, n)| *n)
            .unwrap_or(1);
        json.push_str(&format!(
            "    {{\"name\": \"{}\", \"mean_ns\": {:.1}, \"p50_ns\": {:.1}, \
             \"p95_ns\": {:.1}, \"samples_per_iter\": {}, \"samples_per_sec\": {:.1}}}{}\n",
            json_escape(&r.name),
            r.mean_ns,
            r.p50_ns,
            r.p95_ns,
            n,
            samples_per_sec(r, n),
            if i + 1 == results.len() { "" } else { "," }
        ));
    }
    json.push_str("  ],\n");
    json.push_str(&format!(
        "  \"coordinator_throughput_rps\": {coord_rps:.1},\n"
    ));
    json.push_str("  \"bank_tables_per_sec\": {");
    for (i, (bank, rate)) in bank_rates.iter().enumerate() {
        json.push_str(&format!(
            "\"{bank}\": {rate:.1}{}",
            if i + 1 == bank_rates.len() { "" } else { ", " }
        ));
    }
    json.push_str("},\n");
    json.push_str("  \"kernel_speedup\": {");
    for (i, (bank, s)) in kernel_speedups.iter().enumerate() {
        json.push_str(&format!(
            "\"{bank}\": {}{}",
            s.map(|s| format!("{s:.2}")).unwrap_or_else(|| "null".to_string()),
            if i + 1 == kernel_speedups.len() { "" } else { ", " }
        ));
    }
    json.push_str("},\n");
    json.push_str(&format!(
        "  \"fusion\": {{\"speedup\": {}, \"fused_stages\": {}, \"unfused_stages\": {}, \
         \"stages_folded\": {}}},\n",
        fusion_speedup
            .map(|s| format!("{s:.2}"))
            .unwrap_or_else(|| "null".to_string()),
        fused_mlp.num_stages(),
        unfused_mlp.num_stages(),
        unfused_mlp.num_stages() - fused_mlp.num_stages(),
    ));
    json.push_str(&format!(
        "  \"speedup_batch32_vs_batch1_path\": {}\n",
        speedup.map(|s| format!("{s:.2}")).unwrap_or_else(|| "null".to_string())
    ));
    json.push_str("}\n");
    std::fs::write("BENCH_hotpath.json", &json).expect("write BENCH_hotpath.json");
    println!("\nwrote BENCH_hotpath.json");
}
