//! Hot-path microbenches (the §Perf instrument): LUT bank evaluation vs
//! the multiply-full reference, layer-boundary encodes, coordinator
//! round-trip. This is the bench the performance pass iterates on; its
//! before/after numbers are recorded in EXPERIMENTS.md §Perf.

mod common;

use std::sync::Arc;
use tablenet::config::ServeConfig;
use tablenet::coordinator::Coordinator;
use tablenet::data::synth::Kind;
use tablenet::engine::counters::Counters;
use tablenet::engine::f16enc::acc_vec_to_f16;
use tablenet::engine::plan::EnginePlan;
use tablenet::engine::LutModel;
use tablenet::harness::bench::Bench;
use tablenet::lut::bitplane::DenseBitplaneLut;
use tablenet::lut::dense::DenseWholeLut;
use tablenet::lut::floatplane::{DenseFloatLut, FloatLutConfig};
use tablenet::lut::Partition;
use tablenet::quant::FixedFormat;
use tablenet::tensor::ops::matmul;
use tablenet::tensor::Tensor;
use tablenet::util::Rng;

fn main() {
    let mut rng = Rng::new(1);
    let (p, q) = (10usize, 784usize);
    let w: Vec<f32> = (0..p * q).map(|_| rng.normal() * 0.1).collect();
    let b: Vec<f32> = (0..p).map(|_| rng.normal() * 0.02).collect();
    let x: Vec<f32> = (0..q).map(|_| rng.f32()).collect();

    Bench::header("dense affine 784->10: LUT banks vs reference matmul");
    let mut bench = Bench::default();

    let wt = Tensor::new(&[q, p], {
        // transpose for the reference x@W^T layout
        let mut t = vec![0f32; p * q];
        for o in 0..p {
            for i in 0..q {
                t[i * p + o] = w[o * q + i];
            }
        }
        t
    });
    let xt = Tensor::new(&[1, q], x.clone());
    bench.run("reference matmul f32 (7840 MACs)", || {
        matmul(&xt, &wt).data()[0]
    });

    let plane14 = DenseBitplaneLut::build(
        &w, &b, p, q, Partition::contiguous(q, 14), FixedFormat::new(3),
    )
    .unwrap();
    bench.run("bitplane LUT m=14 r=3 (56 tables)", || {
        let mut c = Counters::default();
        plane14.eval_f32(&x, &mut c)[0]
    });

    let plane1 = DenseBitplaneLut::build(
        &w, &b, p, q, Partition::contiguous(q, 1), FixedFormat::new(3),
    )
    .unwrap();
    bench.run("bitplane LUT m=1 r=3 (784 tables)", || {
        let mut c = Counters::default();
        plane1.eval_f32(&x, &mut c)[0]
    });

    let whole2 = DenseWholeLut::build(
        &w, &b, p, q, Partition::contiguous(q, 2), FixedFormat::new(3),
    )
    .unwrap();
    bench.run("whole-code LUT m=2 r=3 (392 tables)", || {
        let mut c = Counters::default();
        whole2.eval_f32(&x, &mut c)[0]
    });

    let fl = DenseFloatLut::build(
        &w, &b, p, q, Partition::singletons(q), FloatLutConfig::default(),
    )
    .unwrap();
    bench.run("float16-plane LUT m=1 (784 tables)", || {
        let mut c = Counters::default();
        fl.eval_f32(&x, &mut c)[0]
    });

    // quantized-input variants (hot path once input codes are ready)
    let codes: Vec<u32> = x.iter().map(|&v| FixedFormat::new(3).quantize(v)).collect();
    bench.run("bitplane LUT m=14 from codes", || {
        let mut c = Counters::default();
        plane14.eval_codes(&codes, &mut c)[0]
    });

    Bench::header("layer-boundary encode");
    let accs: Vec<i64> = (0..1024).map(|_| (rng.next_u64() >> 20) as i64).collect();
    bench.run("acc -> f16 encode x1024", || {
        let mut c = Counters::default();
        acc_vec_to_f16(&accs, 32, &mut c).len()
    });

    Bench::header("end-to-end: engine infer + coordinator round-trip");
    let (model, ds) = common::linear_model(Kind::Digits);
    let engine = LutModel::compile(&model, &EnginePlan::linear_default()).unwrap();
    let img = ds.test.image(0).to_vec();
    bench.run("linear engine infer (end-to-end)", || {
        engine.infer(&img).class
    });

    let coord = Coordinator::start(
        Arc::new(LutModel::compile(&model, &EnginePlan::linear_default()).unwrap()),
        &ServeConfig { max_batch: 1, max_wait_us: 1, workers: 1, queue_cap: 64 },
    );
    let client = coord.client();
    bench.run("coordinator round-trip (batch=1)", || {
        client.infer_blocking(img.clone()).unwrap().class
    });
    drop(client);
    coord.shutdown();

    if let Some(ratio) = bench.ratio(
        "bitplane LUT m=14 r=3 (56 tables)",
        "reference matmul f32 (7840 MACs)",
    ) {
        println!("\nLUT(m=14) / reference-matmul time ratio: {ratio:.2}x");
    }
}
