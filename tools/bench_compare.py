#!/usr/bin/env python3
"""Bench-regression gate: diff fresh BENCH_*.json against the committed
baseline and fail on throughput regressions.

The benches emit machine-readable JSON (`BENCH_hotpath.json` from
`cargo bench --bench engine_hotpath`, `BENCH_serve.json` from
`cargo bench --bench serve_throughput`, `BENCH_net.json` from
`cargo bench --bench net_throughput`). This script extracts every
higher-is-better throughput metric from them, compares each against
`BENCH_baseline.json`, writes a markdown diff (appended to
`$GITHUB_STEP_SUMMARY` when set, always written to `BENCH_diff.md`),
and exits non-zero when any metric regressed by more than the
threshold (default 15%).

Metrics under the `net/` prefix (the socket-tier soak) are **tracked,
not gated**: loopback TCP throughput on shared CI runners is too noisy
to fail a build on, so their deltas are reported in the table but never
produce a gate failure (including when they go missing). The `kernel/`
prefix (forced scalar-vs-avx2 A/B cases and the derived speedups from
the hotpath bench) is likewise tracked-not-gated: the ratio depends on
the runner's CPU, and a runner without AVX2 legitimately drops the
avx2 cases entirely. The `fusion/` prefix (stage-folding A/B cases and
the derived fused-vs-unfused speedup) is tracked-not-gated while the
fused-plan hotpath metric establishes its baseline; ratchet it into
the gate by moving the prefix out of `is_tracked_only` once a trusted
baseline exists.

Usage:
  tools/bench_compare.py BENCH_baseline.json BENCH_hotpath.json BENCH_serve.json
  tools/bench_compare.py --threshold 0.15 baseline.json fresh1.json [fresh2.json ...]
  tools/bench_compare.py --write-baseline BENCH_baseline.json BENCH_hotpath.json BENCH_serve.json
  tools/bench_compare.py --write-baseline --headroom 0.4 BENCH_baseline.json BENCH_*.json
  tools/bench_compare.py --self-test

`--headroom FRAC` (only with --write-baseline) haircuts every gateable
metric by FRAC before writing, so a baseline ratcheted from one trusted
runner still passes on somewhat slower machines while remaining a real
measured band rather than a made-up floor. Tracked-only metrics are
written as measured.

Baseline schema (BENCH_baseline.json):
  {
    "note":    "free text — provenance of the numbers",
    "metrics": { "<metric name>": <throughput float>, ... }
  }

Metrics present only in the fresh run are reported as NEW (pass);
metrics present only in the baseline are reported as MISSING (fail —
a silently dropped bench case must not pass the gate).
"""

import argparse
import json
import os
import sys

DEFAULT_THRESHOLD = 0.15


def is_tracked_only(name):
    """Metrics reported for trend visibility but never gated."""
    return (
        name.startswith("net/")
        or name.startswith("kernel/")
        or name.startswith("fusion/")
    )


def extract_metrics(doc):
    """Throughput metrics (higher = better) from one BENCH_*.json."""
    bench = doc.get("bench", "unknown")
    out = {}
    if bench == "engine_hotpath":
        for case in doc.get("cases", []):
            sps = case.get("samples_per_sec")
            if sps is None:
                continue
            name = case["name"]
            if name.startswith("kernel:"):
                # Forced-kernel A/B cases: CPU-dependent, tracked only.
                out[f"kernel/{name}/samples_per_sec"] = float(sps)
            elif name.startswith("fusion:"):
                # Stage-folding A/B cases: tracked-not-gated while the
                # fused-plan metric establishes its baseline.
                out[f"fusion/{name}/samples_per_sec"] = float(sps)
            else:
                out[f"hotpath/{name}/samples_per_sec"] = float(sps)
        rps = doc.get("coordinator_throughput_rps")
        if rps is not None:
            out["hotpath/coordinator_throughput_rps"] = float(rps)
        for bank, tps in (doc.get("bank_tables_per_sec") or {}).items():
            if tps is not None:
                out[f"hotpath/bank/{bank}/tables_per_sec"] = float(tps)
        for bank, ratio in (doc.get("kernel_speedup") or {}).items():
            if ratio is not None:
                out[f"kernel/speedup/{bank}"] = float(ratio)
        fusion = doc.get("fusion") or {}
        if fusion.get("speedup") is not None:
            out["fusion/speedup"] = float(fusion["speedup"])
        if fusion.get("stages_folded") is not None:
            out["fusion/stages_folded"] = float(fusion["stages_folded"])
    elif bench == "serve_throughput":
        total = doc.get("total_rps")
        if total is not None:
            out["serve/total_rps"] = float(total)
        for m in doc.get("models", []):
            rps = m.get("rps")
            if rps is not None:
                out[f"serve/{m['name']}/rps"] = float(rps)
    elif bench == "net_throughput":
        total = doc.get("total_rps")
        if total is not None:
            out["net/total_rps"] = float(total)
        for ph in doc.get("phases", []):
            rps = ph.get("rps")
            if rps is not None:
                out[f"net/c{ph['connections']}/rps"] = float(rps)
    else:
        raise SystemExit(f"unrecognised bench document: bench={bench!r}")
    return out


def apply_headroom(metrics, headroom):
    """Haircut gateable metrics by `headroom`; tracked-only stay as measured."""
    if not headroom:
        return dict(metrics)
    return {
        name: value if is_tracked_only(name) else value * (1.0 - headroom)
        for name, value in metrics.items()
    }


def load_fresh(paths):
    metrics = {}
    for p in paths:
        with open(p, encoding="utf-8") as f:
            doc = json.load(f)
        for name, value in extract_metrics(doc).items():
            if name in metrics:
                raise SystemExit(f"duplicate metric {name!r} across inputs")
            metrics[name] = value
    return metrics


def compare(baseline, fresh, threshold):
    """-> (rows, regressions). rows: (name, base, new, delta_str, status)."""
    rows, regressions = [], []
    for name in sorted(set(baseline) | set(fresh)):
        base, new = baseline.get(name), fresh.get(name)
        tracked = is_tracked_only(name)
        if base is None:
            rows.append((name, None, new, "—", "TRACKED" if tracked else "NEW"))
        elif new is None:
            if tracked:
                rows.append((name, base, None, "—", "TRACKED"))
            else:
                rows.append((name, base, None, "—", "MISSING"))
                regressions.append(
                    f"{name}: present in baseline but not in the fresh run"
                )
        else:
            delta = (new - base) / base if base > 0 else 0.0
            status = "TRACKED" if tracked else "OK"
            if delta < -threshold and not tracked:
                status = "REGRESSED"
                regressions.append(
                    f"{name}: {base:.1f} -> {new:.1f} ({delta:+.1%}, "
                    f"allowed -{threshold:.0%})"
                )
            rows.append((name, base, new, f"{delta:+.1%}", status))
    return rows, regressions


def fmt(v):
    return "—" if v is None else f"{v:,.1f}"


def markdown(rows, regressions, threshold, note):
    lines = ["## Bench regression gate", ""]
    if note:
        lines += [f"_baseline: {note}_", ""]
    lines += [
        f"Threshold: fail below **-{threshold:.0%}** vs baseline (throughput, higher is better).",
        "",
        "| metric | baseline | fresh | delta | status |",
        "|---|---:|---:|---:|---|",
    ]
    for name, base, new, delta, status in rows:
        badge = {
            "OK": "✅",
            "NEW": "🆕",
            "MISSING": "❌",
            "REGRESSED": "❌",
            "TRACKED": "📈",
        }[status]
        lines.append(f"| `{name}` | {fmt(base)} | {fmt(new)} | {delta} | {badge} {status} |")
    lines.append("")
    if regressions:
        lines.append(f"**{len(regressions)} gate failure(s):**")
        lines += [f"- {r}" for r in regressions]
    else:
        lines.append("**Gate passed.**")
    lines.append("")
    return "\n".join(lines)


def self_test():
    doc_hot = {
        "bench": "engine_hotpath",
        "cases": [
            {"name": "a", "samples_per_sec": 100.0},
            {"name": "b", "samples_per_sec": 50.0},
            {"name": "kernel:avx2 a", "samples_per_sec": 300.0},
            {"name": "fusion:fused a", "samples_per_sec": 80.0},
        ],
        "coordinator_throughput_rps": 1000.0,
        "bank_tables_per_sec": {"bitplane_m14": 2.0e6},
        "kernel_speedup": {"bitplane": 3.0, "float": None},
        "fusion": {
            "speedup": 1.1,
            "fused_stages": 3,
            "unfused_stages": 7,
            "stages_folded": 4,
        },
    }
    doc_serve = {
        "bench": "serve_throughput",
        "total_rps": 500.0,
        "models": [{"name": "m0", "rps": 250.0}],
    }
    doc_net = {
        "bench": "net_throughput",
        "total_rps": 900.0,
        "phases": [
            {"connections": 2, "rps": 400.0},
            {"connections": 8, "rps": 500.0},
        ],
    }
    fresh = {}
    for d in (doc_hot, doc_serve, doc_net):
        fresh.update(extract_metrics(d))
    assert fresh["hotpath/a/samples_per_sec"] == 100.0
    assert fresh["serve/total_rps"] == 500.0
    assert fresh["net/c2/rps"] == 400.0
    # kernel: cases route to the tracked kernel/ prefix, not hotpath/
    assert fresh["kernel/kernel:avx2 a/samples_per_sec"] == 300.0
    assert "hotpath/kernel:avx2 a/samples_per_sec" not in fresh
    # per-bank table throughput is gated; null speedups are dropped
    assert fresh["hotpath/bank/bitplane_m14/tables_per_sec"] == 2.0e6
    assert fresh["kernel/speedup/bitplane"] == 3.0
    assert "kernel/speedup/float" not in fresh
    # fusion: cases and derived metrics route to the tracked fusion/ prefix
    assert fresh["fusion/fusion:fused a/samples_per_sec"] == 80.0
    assert "hotpath/fusion:fused a/samples_per_sec" not in fresh
    assert fresh["fusion/speedup"] == 1.1
    assert fresh["fusion/stages_folded"] == 4.0
    assert len(fresh) == 14, fresh

    # net/, kernel/ and fusion/ metrics are tracked, never gated: a 90%
    # collapse and an outright disappearance both pass
    base = dict(fresh)
    base["net/total_rps"] = 9000.0
    base["net/gone/rps"] = 123.0
    base["kernel/speedup/bitplane"] = 30.0
    base["kernel/kernel:gone/samples_per_sec"] = 1.0
    base["fusion/speedup"] = 11.0
    rows, reg = compare(base, fresh, 0.15)
    assert not reg, reg
    statuses = {r[0]: r[4] for r in rows}
    assert statuses["net/total_rps"] == "TRACKED", statuses
    assert statuses["net/gone/rps"] == "TRACKED", statuses
    assert statuses["kernel/speedup/bitplane"] == "TRACKED", statuses
    assert statuses["kernel/kernel:gone/samples_per_sec"] == "TRACKED", statuses
    assert statuses["fusion/speedup"] == "TRACKED", statuses

    # headroom haircuts gateable metrics only
    cut = apply_headroom(fresh, 0.4)
    assert cut["hotpath/a/samples_per_sec"] == 60.0, cut
    assert cut["hotpath/bank/bitplane_m14/tables_per_sec"] == 1.2e6, cut
    assert cut["kernel/speedup/bitplane"] == 3.0, cut
    assert cut["net/total_rps"] == 900.0, cut
    assert cut["fusion/speedup"] == 1.1, cut
    assert apply_headroom(fresh, 0.0) == fresh

    # within threshold: pass (13% down on one metric)
    base = dict(fresh)
    base["hotpath/a/samples_per_sec"] = 115.0
    rows, reg = compare(base, fresh, 0.15)
    assert not reg, reg
    assert [r for r in rows if r[4] == "OK"], rows

    # beyond threshold: fail
    base["hotpath/a/samples_per_sec"] = 200.0
    _, reg = compare(base, fresh, 0.15)
    assert len(reg) == 1 and "hotpath/a" in reg[0], reg

    # improvements and new metrics pass; dropped metrics fail
    base = {"hotpath/a/samples_per_sec": 10.0, "gone/metric": 1.0}
    rows, reg = compare(base, fresh, 0.15)
    assert len(reg) == 1 and "gone/metric" in reg[0], reg
    statuses = {r[0]: r[4] for r in rows}
    assert statuses["hotpath/a/samples_per_sec"] == "OK"
    assert statuses["serve/total_rps"] == "NEW"
    assert statuses["gone/metric"] == "MISSING"

    # markdown renders every row
    md = markdown(rows, reg, 0.15, "self-test")
    assert "REGRESSED" in md or "MISSING" in md
    print("self-test passed")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", nargs="?", help="BENCH_baseline.json")
    ap.add_argument("fresh", nargs="*", help="fresh BENCH_*.json files")
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help="max allowed fractional regression (default 0.15)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write BASELINE from the fresh files instead of comparing")
    ap.add_argument("--headroom", type=float, default=0.0, metavar="FRAC",
                    help="with --write-baseline: haircut gateable metrics by "
                         "FRAC (0..1) so the baseline tolerates slower runners")
    ap.add_argument("--out", default="BENCH_diff.md", help="markdown diff output path")
    ap.add_argument("--self-test", action="store_true")
    args = ap.parse_args()

    if args.self_test:
        self_test()
        return 0
    if not args.baseline or not args.fresh:
        ap.error("need a baseline and at least one fresh BENCH_*.json")

    if args.headroom and not args.write_baseline:
        ap.error("--headroom only makes sense with --write-baseline")
    if not 0.0 <= args.headroom < 1.0:
        ap.error("--headroom must be in [0, 1)")

    fresh = load_fresh(args.fresh)
    if args.write_baseline:
        metrics = apply_headroom(fresh, args.headroom)
        note = "generated by tools/bench_compare.py --write-baseline"
        if args.headroom:
            note += f" --headroom {args.headroom:g}"
        doc = {"note": note, "metrics": metrics}
        with open(args.baseline, "w", encoding="utf-8") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.baseline} ({len(metrics)} metrics)")
        return 0

    with open(args.baseline, encoding="utf-8") as f:
        base_doc = json.load(f)
    rows, regressions = compare(base_doc.get("metrics", {}), fresh, args.threshold)
    md = markdown(rows, regressions, args.threshold, base_doc.get("note", ""))

    with open(args.out, "w", encoding="utf-8") as f:
        f.write(md)
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a", encoding="utf-8") as f:
            f.write(md)
    print(md)
    if regressions:
        print(f"FAIL: {len(regressions)} bench metric(s) regressed beyond "
              f"{args.threshold:.0%}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
