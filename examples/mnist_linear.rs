//! End-to-end linear classifier: loads the JAX-trained weights (or
//! trains in-Rust as a fallback), compiles the paper's headline LUT
//! configuration ("56 LUTs, 17.5 MiB, 168 LUT evaluations"), and
//! reports accuracy + op counts for the LUT engine vs the reference.
//!
//!     cargo run --release --example mnist_linear [-- --dataset fashion]

use std::path::Path;
use tablenet::config::cli::Args;
use tablenet::data::synth::Kind;
use tablenet::data::load_or_generate;
use tablenet::engine::plan::EnginePlan;
use tablenet::engine::Compiler;
use tablenet::nn::{weights, Arch};
use tablenet::tensor::Tensor;
use tablenet::train::{train_dense, TrainConfig};
use tablenet::util::{fmt_bits, fmt_ops};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let kind = Kind::parse(args.get_or("dataset", "mnist")).expect("mnist|fashion");
    let ds = load_or_generate(Path::new("data/synth"), kind, 6000, 1000, 7)?;

    // prefer the JAX-trained artifact; fall back to in-Rust training
    let wpath = match kind {
        Kind::Digits => "artifacts/weights_linear.bin",
        Kind::Fashion => "artifacts/weights_linear_fashion.bin",
    };
    let model = match weights::load_model(Arch::Linear, Path::new(wpath)) {
        Ok(m) => {
            println!("loaded {wpath}");
            m
        }
        Err(_) => {
            println!("no artifact found; training in-Rust (~10 s)...");
            train_dense(
                &ds.train,
                &[784, 10],
                &TrainConfig { steps: 3000, lr: 0.2, input_bits: Some(3), ..Default::default() },
            )
        }
    };

    // reference accuracy (full precision, multiply-full)
    let x = Tensor::new(&[ds.test.len(), 784], ds.test.images.clone());
    let ref_acc = model.accuracy(&x, &ds.test.labels);

    // the paper's two named configs
    for (name, plan) in [
        ("56 LUTs (m=14)", EnginePlan::linear_default()),
        ("784 LUTs (m=1, memory parity)", EnginePlan::linear_parity()),
    ] {
        let lut = Compiler::new(&model).plan(&plan).build().expect("materialisable");
        let (acc, ctr) = lut.accuracy(&ds.test.images, 784, &ds.test.labels);
        ctr.assert_multiplier_less();
        println!(
            "\n{name}: size {}  accuracy {:.2}% (ref {:.2}%)",
            fmt_bits(lut.size_bits()),
            acc * 100.0,
            ref_acc * 100.0
        );
        println!(
            "  per inference: {} LUT evals, {} shift-adds, {} adds, 0 multiplies",
            ctr.lut_evals,
            fmt_ops(ctr.shift_adds),
            fmt_ops(ctr.adds)
        );
        println!(
            "  reference does {} multiply-and-adds for the same layer",
            fmt_ops(7840)
        );
    }
    Ok(())
}
