//! Quickstart: build a multiplier-less LUT implementation of a small
//! dense layer and verify it against the float reference — the paper's
//! core construction in ~60 lines of user code. Needs no artifacts.
//!
//!     cargo run --release --example quickstart

use tablenet::engine::counters::Counters;
use tablenet::lut::bitplane::DenseBitplaneLut;
use tablenet::lut::{from_acc, Partition};
use tablenet::quant::FixedFormat;
use tablenet::util::{fmt_bits, Rng};

fn main() {
    // a 16 -> 4 dense layer with random weights
    let (p, q) = (4usize, 16usize);
    let mut rng = Rng::new(42);
    let w: Vec<f32> = (0..p * q).map(|_| rng.normal() * 0.5).collect();
    let b: Vec<f32> = (0..p).map(|_| rng.normal() * 0.1).collect();

    // input quantized to 4 bits, partitioned into chunks of 4 elements:
    // one 2^4-row table per chunk, reused across all 4 bitplanes
    let fmt = FixedFormat::new(4);
    let partition = Partition::contiguous(q, 4);
    let lut = DenseBitplaneLut::build(&w, &b, p, q, partition, fmt)
        .expect("table fits comfortably in memory");

    let x: Vec<f32> = (0..q).map(|_| rng.f32()).collect();

    // multiplier-less evaluation: gathers + shift-adds only
    let mut ctr = Counters::default();
    let acc = lut.eval_f32(&x, &mut ctr);
    let lut_out: Vec<f32> = acc.iter().map(|&a| from_acc(a, 0)).collect();

    // float reference on the same (quantized) input
    let xq: Vec<f32> = x.iter().map(|&v| fmt.fake_quant(v)).collect();
    let ref_out: Vec<f32> = (0..p)
        .map(|o| b[o] + (0..q).map(|i| w[o * q + i] * xq[i]).sum::<f32>())
        .collect();

    println!("input (first 6):  {:?}", &x[..6]);
    println!("LUT output:       {lut_out:?}");
    println!("float reference:  {ref_out:?}");
    let max_err = lut_out
        .iter()
        .zip(&ref_out)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("max |Δ| = {max_err:.2e}");
    assert!(max_err < 1e-4);

    println!("\nop mix for one inference: {ctr}");
    ctr.assert_multiplier_less();
    println!(
        "table storage: {} (vs {} for f32 weights)",
        fmt_bits(lut.size_bits(16)),
        fmt_bits((p * q * 32) as u64),
    );
    println!("\nquickstart OK — zero multiplies on the data path.");
}
