//! Multi-model serving: one router, two LUT engines (digits + fashion
//! linear classifiers), independently batched pipelines — the
//! multi-tenant edge-deployment shape the paper's concluding remarks
//! motivate ("having a LUT at each sensor").
//!
//!     cargo run --release --example multi_model -- [--requests 2000]

use std::path::Path;
use std::sync::Arc;
use tablenet::config::cli::Args;
use tablenet::config::ServeConfig;
use tablenet::coordinator::router::Router;
use tablenet::coordinator::Backend;
use tablenet::data::synth::Kind;
use tablenet::data::load_or_generate;
use tablenet::engine::plan::EnginePlan;
use tablenet::engine::Compiler;
use tablenet::nn::{weights, Arch};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let n_requests = args.get_usize("requests", 2000);

    let digits = load_or_generate(Path::new("data/synth"), Kind::Digits, 6000, 1000, 7)?;
    let fashion = load_or_generate(Path::new("data/synth"), Kind::Fashion, 6000, 1000, 7)?;

    let mk = |path: &str| -> anyhow::Result<Arc<dyn Backend>> {
        let model = weights::load_model(Arch::Linear, Path::new(path))?;
        Ok(Arc::new(Compiler::new(&model).plan(&EnginePlan::linear_default()).build().unwrap()))
    };
    let router = Router::start(
        vec![
            ("digits".to_string(), mk("artifacts/weights_linear.bin")?),
            ("fashion".to_string(), mk("artifacts/weights_linear_fashion.bin")?),
        ],
        &ServeConfig { max_batch: 32, max_wait_us: 200, workers: 1, queue_cap: 512 },
    );
    println!("serving models: {:?}", router.models());

    let client = router.client();
    let t0 = std::time::Instant::now();
    let mut correct = [0usize; 2];
    let mut served = [0usize; 2];
    for i in 0..n_requests {
        // interleave traffic across tenants
        let (name, ds, slot) = if i % 2 == 0 {
            ("digits", &digits, 0)
        } else {
            ("fashion", &fashion, 1)
        };
        let idx = (i / 2) % ds.test.len();
        let resp = client.infer(name, ds.test.image(idx).to_vec())?;
        served[slot] += 1;
        if resp.class == ds.test.labels[idx] {
            correct[slot] += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    let snaps = router.shutdown();
    for (name, snap) in &snaps {
        println!("\n[{name}]\n{snap}");
        snap.ops.assert_multiplier_less();
    }
    println!(
        "\ndigits acc {:.1}%  fashion acc {:.1}%  | {:.0} req/s total",
        100.0 * correct[0] as f64 / served[0] as f64,
        100.0 * correct[1] as f64 / served[1] as f64,
        n_requests as f64 / wall
    );
    println!("both tenants multiplier-less ✓");
    Ok(())
}
