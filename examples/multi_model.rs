//! Multi-model fleet serving: one registry, two LUT engines (digits +
//! fashion linear classifiers) loaded from `.ltm` artifacts and served
//! behind independently-batched pipelines — the multi-tenant edge
//! deployment the paper's concluding remarks motivate ("having a LUT
//! at each sensor"). Exercises the full fleet lifecycle under load:
//! register both tenants, hot-swap the digits model to a v2 mid-run
//! (zero requests lost, versions never mixed in a batch), then retire
//! the fashion model and show routing to it fails cleanly while digits
//! keeps serving.
//!
//!     cargo run --release --example multi_model -- [--requests 2000]

use std::path::Path;
use std::sync::Arc;
use tablenet::config::cli::Args;
use tablenet::config::ServeConfig;
use tablenet::coordinator::registry::ModelRegistry;
use tablenet::coordinator::router::RouteError;
use tablenet::data::load_or_generate;
use tablenet::data::synth::Kind;
use tablenet::data::Split;
use tablenet::engine::plan::{AffineMode, EnginePlan};
use tablenet::engine::{Compiler, LutModel};
use tablenet::nn::{weights, Arch, Model};
use tablenet::train::{train_dense, TrainConfig};

/// Load trained linear weights, or train a quick in-Rust replacement so
/// the example runs from a bare checkout.
fn linear_model(wpath: &str, train: &Split) -> anyhow::Result<Model> {
    match weights::load_model(Arch::Linear, Path::new(wpath)) {
        Ok(m) => Ok(m),
        Err(e) => {
            println!("({e}); training in-Rust instead");
            Ok(train_dense(
                train,
                &[784, 10],
                &TrainConfig { steps: 1500, lr: 0.2, ..Default::default() },
            ))
        }
    }
}

/// Compile to a `.ltm`, then serve from the artifact — never the
/// weights — mirroring a real deployment.
fn compile_artifact(model: &Model, plan: &EnginePlan, path: &str) -> anyhow::Result<LutModel> {
    let lut = Compiler::new(model).plan(plan).build().expect("plan materialises");
    std::fs::create_dir_all("artifacts")?;
    lut.save(Path::new(path))?;
    Ok(LutModel::load(Path::new(path))?)
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let n_requests = args.get_usize("requests", 2000);

    let digits = load_or_generate(Path::new("data/synth"), Kind::Digits, 6000, 1000, 7)?;
    let fashion = load_or_generate(Path::new("data/synth"), Kind::Fashion, 6000, 1000, 7)?;

    let digits_model = linear_model("artifacts/weights_linear.bin", &digits.train)?;
    let fashion_model = linear_model("artifacts/weights_linear_fashion.bin", &fashion.train)?;
    let plan = EnginePlan::linear_default();

    let registry = ModelRegistry::new();
    // per-model batching policies: the digits tenant takes bursty
    // traffic (bigger batches), fashion stays latency-tight
    registry.register(
        "digits",
        Arc::new(compile_artifact(&digits_model, &plan, "artifacts/model_digits.ltm")?),
        &ServeConfig {
            max_batch: 32,
            max_wait_us: 200,
            workers: 1,
            queue_cap: 512,
            ..ServeConfig::default()
        },
    )?;
    registry.register(
        "fashion",
        Arc::new(compile_artifact(&fashion_model, &plan, "artifacts/model_fashion.ltm")?),
        &ServeConfig {
            max_batch: 8,
            max_wait_us: 50,
            workers: 1,
            queue_cap: 512,
            ..ServeConfig::default()
        },
    )?;
    for info in registry.models() {
        println!("serving '{}' v{} ({}, {} workers)", info.name, info.version, info.backend, info.workers);
    }

    let client = registry.client();
    let t0 = std::time::Instant::now();
    let mut correct = [0usize; 2];
    let mut served = [0usize; 2];
    let mut digits_v2 = 0usize;
    let swap_at = n_requests / 2;
    let retire_at = n_requests * 3 / 4;
    let mut fashion_retired = false;
    for i in 0..n_requests {
        if i == swap_at {
            // rolling deployment: digits v2 (sharper input bits) goes
            // live under load; in-flight batches finish on v1
            let v2_plan = EnginePlan {
                affine: vec![AffineMode::BitplaneFixed { bits: 4, m: 14, range_exp: 0 }],
                fallback: AffineMode::Float { planes: 11, m: 1 },
                r_o: 16,
            };
            let v2 =
                compile_artifact(&digits_model, &v2_plan, "artifacts/model_digits_v2.ltm")?;
            let version = registry.swap("digits", Arc::new(v2))?;
            println!("[{i}] hot-swapped 'digits' -> v{version}");
        }
        if i == retire_at {
            let snap = registry.retire("fashion")?;
            println!(
                "[{i}] retired 'fashion' after {} requests (drained, zero lost)",
                snap.completed
            );
            fashion_retired = true;
        }
        // interleave traffic across tenants; after retirement the
        // fashion share routes must fail cleanly, never hang
        let (name, ds, slot) = if i % 2 == 0 {
            ("digits", &digits, 0)
        } else {
            ("fashion", &fashion, 1)
        };
        let idx = (i / 2) % ds.test.len();
        match client.infer(name, ds.test.image(idx).to_vec()) {
            Ok(resp) => {
                served[slot] += 1;
                if resp.class == ds.test.labels[idx] {
                    correct[slot] += 1;
                }
                if name == "digits" && resp.version >= 2 {
                    digits_v2 += 1;
                }
            }
            Err(RouteError::UnknownModel(m)) => {
                assert!(fashion_retired && m == "fashion", "unexpected unknown model {m}");
            }
            Err(e) => return Err(e.into()),
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    let fleet = registry.shutdown();
    println!("\n{fleet}");
    fleet.assert_multiplier_less();
    println!(
        "\ndigits acc {:.1}% ({} served, {} by v2)  fashion acc {:.1}% ({} served before retirement)",
        100.0 * correct[0] as f64 / served[0].max(1) as f64,
        served[0],
        digits_v2,
        100.0 * correct[1] as f64 / served[1].max(1) as f64,
        served[1],
    );
    println!(
        "{:.0} req/s total | every tenant multiplier-less, swap + retire under load ✓",
        (served[0] + served[1]) as f64 / wall
    );
    Ok(())
}
