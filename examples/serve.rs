//! END-TO-END DRIVER: loads a trained model, compiles the
//! multiplier-less engine, starts the serving coordinator (router +
//! dynamic batcher + worker pool), drives it with concurrent clients on
//! a real workload, and reports latency percentiles, throughput,
//! accuracy and the aggregate op counters (proving zero multiplies
//! across the whole serve run). This exercises every layer: artifacts
//! (L2-trained weights) -> LUT banks (L1 semantics) -> coordinator (L3).
//!
//!     cargo run --release --example serve -- \
//!         [--arch linear|mlp] [--requests 2000] [--clients 4] \
//!         [--max-batch 32] [--max-wait-us 500]

use std::path::Path;
use std::sync::Arc;
use tablenet::config::cli::Args;
use tablenet::config::ServeConfig;
use tablenet::coordinator::Coordinator;
use tablenet::data::synth::Kind;
use tablenet::data::load_or_generate;
use tablenet::engine::plan::EnginePlan;
use tablenet::engine::Compiler;
use tablenet::nn::{weights, Arch};
use tablenet::train::{train_dense, TrainConfig};
use tablenet::util::fmt_bits;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let arch = Arch::parse(args.get_or("arch", "linear")).expect("linear|mlp|cnn");
    let ds = load_or_generate(Path::new("data/synth"), Kind::Digits, 6000, 1000, 7)?;

    let wpath = format!("artifacts/weights_{}.bin", arch.name());
    let model = match weights::load_model(arch, Path::new(&wpath)) {
        Ok(m) => {
            println!("loaded {wpath}");
            m
        }
        Err(e) if arch == Arch::Linear => {
            println!("({e}); training linear in-Rust instead");
            train_dense(
                &ds.train,
                &[784, 10],
                &TrainConfig { steps: 3000, lr: 0.2, ..Default::default() },
            )
        }
        Err(e) => return Err(e),
    };

    let plan = EnginePlan::default_for(arch);
    let engine = Compiler::new(&model).plan(&plan).build().expect("default plan materialises");
    println!(
        "engine: {} of LUTs, plan {:?}",
        fmt_bits(engine.size_bits()),
        plan.affine
    );

    let cfg = ServeConfig {
        max_batch: args.get_usize("max-batch", 32),
        max_wait_us: args.get_u64("max-wait-us", 500),
        workers: args.get_usize("workers", 1),
        queue_cap: args.get_usize("queue-cap", 1024),
    };
    cfg.validate()?;
    let n_requests = args.get_usize("requests", 2000);
    let n_clients = args.get_usize("clients", 4).max(1);

    let coord = Coordinator::start(Arc::new(engine), &cfg);
    let test = Arc::new(ds.test);
    let t0 = std::time::Instant::now();
    let mut joins = Vec::new();
    for c in 0..n_clients {
        let client = coord.client();
        let test = test.clone();
        let n = n_requests / n_clients;
        joins.push(std::thread::spawn(move || {
            let mut correct = 0usize;
            for i in 0..n {
                let idx = (c * n + i) % test.len();
                let resp = client
                    .infer_blocking(test.image(idx).to_vec())
                    .expect("coordinator alive");
                if resp.class == test.labels[idx] {
                    correct += 1;
                }
            }
            (n, correct)
        }));
    }
    let (mut served, mut correct) = (0usize, 0usize);
    for j in joins {
        let (s, c) = j.join().unwrap();
        served += s;
        correct += c;
    }
    let wall = t0.elapsed().as_secs_f64();
    let snap = coord.shutdown();

    println!("\n=== serve report ({} clients, batch<= {}) ===", n_clients, cfg.max_batch);
    println!("{snap}");
    println!(
        "\nwall: {wall:.2}s -> {:.0} req/s | accuracy {:.2}% over {served} requests",
        served as f64 / wall,
        100.0 * correct as f64 / served as f64
    );
    snap.ops.assert_multiplier_less();
    println!("multiplier-less invariant held across the entire run ✓");
    Ok(())
}
