//! END-TO-END DRIVER: trains (or loads) a model, compiles it to a
//! servable `.ltm` artifact, starts the registry serving runtime from
//! the ARTIFACT ALONE (the deployment shape — no weights on the serve
//! path), drives it with concurrent clients on a real workload, and
//! hot-swaps a freshly compiled v2 mid-load: zero requests lost, no
//! batch mixes versions, and the whole run stays multiplier-less.
//! This exercises every layer: trained weights (L2) -> compiled LUT
//! artifact (L1 semantics) -> registry/batcher/workers (L3).
//!
//!     cargo run --release --example serve -- \
//!         [--arch linear|mlp] [--requests 2000] [--clients 4] \
//!         [--max-batch 32] [--max-wait-us 500]

use std::path::Path;
use std::sync::Arc;
use tablenet::config::cli::Args;
use tablenet::config::ServeConfig;
use tablenet::coordinator::registry::ModelRegistry;
use tablenet::data::load_or_generate;
use tablenet::data::synth::Kind;
use tablenet::engine::plan::{AffineMode, EnginePlan};
use tablenet::engine::{Compiler, LutModel};
use tablenet::nn::{weights, Arch};
use tablenet::train::{train_dense, TrainConfig};
use tablenet::util::fmt_bits;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let arch = Arch::parse(args.get_or("arch", "linear")).expect("linear|mlp|cnn");
    let ds = load_or_generate(Path::new("data/synth"), Kind::Digits, 6000, 1000, 7)?;

    let wpath = format!("artifacts/weights_{}.bin", arch.name());
    let model = match weights::load_model(arch, Path::new(&wpath)) {
        Ok(m) => {
            println!("loaded {wpath}");
            m
        }
        Err(e) if arch == Arch::Linear => {
            println!("({e}); training linear in-Rust instead");
            train_dense(
                &ds.train,
                &[784, 10],
                &TrainConfig { steps: 3000, lr: 0.2, ..Default::default() },
            )
        }
        Err(e) => return Err(e),
    };

    // compile -> artifact -> load: serve from the .ltm, not the weights
    let plan = EnginePlan::default_for(arch);
    let v1 = Compiler::new(&model).plan(&plan).build().expect("default plan materialises");
    std::fs::create_dir_all("artifacts")?;
    let ltm = format!("artifacts/model_{}.ltm", arch.name());
    v1.save(Path::new(&ltm))?;
    let engine = LutModel::load(Path::new(&ltm))?;
    println!(
        "serving artifact {ltm}: {} of LUTs, plan {:?}",
        fmt_bits(engine.size_bits()),
        engine.plan().affine
    );

    let cfg = ServeConfig {
        max_batch: args.get_usize("max-batch", 32),
        max_wait_us: args.get_u64("max-wait-us", 500),
        workers: args.get_usize("workers", 1),
        queue_cap: args.get_usize("queue-cap", 1024),
        ..ServeConfig::default()
    };
    let n_requests = args.get_usize("requests", 2000);
    let n_clients = args.get_usize("clients", 4).max(1);

    let registry = ModelRegistry::new();
    registry.register("primary", Arc::new(engine), &cfg)?;

    let client_handle = registry.client();
    let test = Arc::new(ds.test);
    let t0 = std::time::Instant::now();
    let mut joins = Vec::new();
    for c in 0..n_clients {
        let client = client_handle.clone();
        let test = test.clone();
        let n = n_requests / n_clients;
        joins.push(std::thread::spawn(move || {
            let mut correct = 0usize;
            let mut v2_seen = 0usize;
            for i in 0..n {
                let idx = (c * n + i) % test.len();
                let resp = client
                    .infer("primary", test.image(idx).to_vec())
                    .expect("registry alive");
                if resp.class == test.labels[idx] {
                    correct += 1;
                }
                if resp.version >= 2 {
                    v2_seen += 1;
                }
            }
            (n, correct, v2_seen)
        }));
    }

    // rolling deployment under load: recompile with a sharper input
    // quantization and hot-swap it in; in-flight batches finish on v1
    let planned = (n_requests / n_clients) * n_clients;
    while registry.fleet_completed() < (planned / 2) as u64 {
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    let plan_v2 = match arch {
        Arch::Linear => EnginePlan {
            affine: vec![AffineMode::BitplaneFixed { bits: 4, m: 14, range_exp: 0 }],
            fallback: AffineMode::Float { planes: 11, m: 1 },
            r_o: 16,
        },
        _ => plan.clone(),
    };
    let v2 = Compiler::new(&model).plan(&plan_v2).build().expect("v2 plan materialises");
    let version = registry.swap("primary", Arc::new(v2))?;
    println!("hot-swapped 'primary' -> version {version} (input bits bumped)");

    let (mut served, mut correct, mut v2_seen) = (0usize, 0usize, 0usize);
    for j in joins {
        let (s, c, v) = j.join().unwrap();
        served += s;
        correct += c;
        v2_seen += v;
    }
    let wall = t0.elapsed().as_secs_f64();
    let fleet = registry.shutdown();

    println!("\n=== serve report ({n_clients} clients, batch <= {}) ===", cfg.max_batch);
    println!("{fleet}");
    println!(
        "\nwall: {wall:.2}s -> {:.0} req/s | accuracy {:.2}% over {served} requests \
         ({v2_seen} served by v2)",
        served as f64 / wall,
        100.0 * correct as f64 / served as f64
    );
    assert_eq!(fleet.completed() as usize, served, "a request went missing");
    fleet.assert_multiplier_less();
    println!("zero requests lost across the swap; multiplier-less invariant held ✓");
    Ok(())
}
