//! Ablation: mantissa-bitplane truncation (the paper's closing
//! question — "determining what the optimal architecture should be to
//! balance the LUT size and the number of operations").
//!
//! The binary16 LUT path evaluates one lookup per mantissa plane; the
//! top planes carry most of the signal, so truncating low planes trades
//! ops (linearly) against accuracy. This sweep measures that trade on
//! the real MLP artifacts.
//!
//!     cargo run --release --example ablation_planes -- [--n 200]

use std::path::Path;
use tablenet::config::cli::Args;
use tablenet::data::synth::Kind;
use tablenet::data::load_or_generate;
use tablenet::engine::plan::{AffineMode, EnginePlan};
use tablenet::engine::Compiler;
use tablenet::nn::{weights, Arch};
use tablenet::util::fmt_ops;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let n = args.get_usize("n", 200);
    let ds = load_or_generate(Path::new("data/synth"), Kind::Digits, 6000, 1000, 7)?;
    let test = ds.test.head(n);

    let model = weights::load_model(Arch::Mlp, Path::new("artifacts/weights_mlp.bin"))
        .map_err(|e| anyhow::anyhow!("{e}\nrun `make artifacts` first"))?;

    println!(
        "{:>7} {:>10} {:>14} {:>14} {:>12}",
        "planes", "accuracy", "lut evals", "shift-adds", "ms/infer"
    );
    for planes in [11u32, 9, 7, 5, 4, 3, 2] {
        let plan = EnginePlan {
            affine: vec![
                AffineMode::Float { planes, m: 1 },
                AffineMode::Float { planes, m: 1 },
                AffineMode::Float { planes, m: 1 },
            ],
            fallback: AffineMode::Float { planes, m: 1 },
            r_o: 16,
        };
        let lut = Compiler::new(&model).plan(&plan).build().expect("materialisable");
        let t0 = std::time::Instant::now();
        let (acc, ctr) = lut.accuracy(&test.images, 784, &test.labels);
        let ms = t0.elapsed().as_secs_f64() * 1000.0 / n as f64;
        ctr.assert_multiplier_less();
        println!(
            "{:>7} {:>9.1}% {:>14} {:>14} {:>12.2}",
            planes,
            acc * 100.0,
            fmt_ops(ctr.lut_evals),
            fmt_ops(ctr.shift_adds),
            ms
        );
    }
    println!("\ntakeaway: the top ~5 mantissa planes carry nearly all the accuracy;");
    println!("ops scale ~linearly with planes — a free 2x op reduction vs the");
    println!("paper's full 11-plane configuration.");
    Ok(())
}
