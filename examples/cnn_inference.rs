//! LeNet CNN through the LUT engine: compiles the paper's CNN
//! configuration (8-bit fixed conv1 with 2x2 spatial blocks; binary16
//! single-element partitions for conv2/fc1/fc2), runs inferences and
//! prints the per-layer cost breakdown next to the reference MACs —
//! the substance of the paper's Deep CNN section.
//!
//!     cargo run --release --example cnn_inference -- [--n 20]

use std::path::Path;
use tablenet::config::cli::Args;
use tablenet::data::synth::Kind;
use tablenet::data::load_or_generate;
use tablenet::engine::plan::EnginePlan;
use tablenet::engine::Compiler;
use tablenet::nn::{weights, Arch};
use tablenet::planner::{arch_geometry, evaluate_plan};
use tablenet::tensor::Tensor;
use tablenet::util::{fmt_bits, fmt_ops};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let n = args.get_usize("n", 20);
    let ds = load_or_generate(Path::new("data/synth"), Kind::Digits, 6000, 1000, 7)?;

    let model = weights::load_model(Arch::Cnn, Path::new("artifacts/weights_cnn.bin"))
        .map_err(|e| anyhow::anyhow!("{e}\nrun `make artifacts` first"))?;

    let plan = EnginePlan::cnn_default();
    let pt = evaluate_plan(&arch_geometry(Arch::Cnn), &plan);
    println!("plan: {}", pt.label);
    println!(
        "planner: {} LUTs, {}, {} shift-adds vs {} reference MACs",
        pt.num_luts,
        fmt_bits(pt.size_bits),
        fmt_ops(pt.ops),
        fmt_ops(pt.ref_macs)
    );

    println!("compiling LUT banks (builds tables for all 4 layers)...");
    let t0 = std::time::Instant::now();
    let lut = Compiler::new(&model).plan(&plan).build().expect("cnn default materialises");
    println!("compiled in {:.1}s, {} resident", t0.elapsed().as_secs_f64(), fmt_bits(lut.size_bits()));

    // reference accuracy on the same subset
    let test = ds.test.head(n);
    let x = Tensor::new(&[test.len(), 28, 28, 1], test.images.clone());
    let ref_acc = model.accuracy(&x, &test.labels);

    let t1 = std::time::Instant::now();
    let (acc, ctr) = lut.accuracy(&test.images, 784, &test.labels);
    let per_inf = t1.elapsed().as_secs_f64() / n as f64;
    ctr.assert_multiplier_less();

    println!("\nLUT engine:  {:.1}% over {n} samples ({per_inf:.2}s/inference interpretively)", acc * 100.0);
    println!("reference:   {:.1}%", ref_acc * 100.0);
    println!("per-inference ops: {ctr}");
    println!("\nzero multiplies across a 4-layer CNN ✓");
    Ok(())
}
