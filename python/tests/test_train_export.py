"""Training smoke tests + TBNW export round-trips + AOT lowering."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, export, train
from compile import model as M


def synthetic_blob_dataset(n=400, seed=0):
    """Tiny linearly-separable-ish 10-class image dataset: one bright
    blob per class at a class-specific location."""
    rng = np.random.default_rng(seed)
    x = rng.uniform(0.0, 0.15, size=(n, 28, 28)).astype(np.float32)
    y = (np.arange(n) % 10).astype(np.int32)
    for i in range(n):
        c = y[i]
        cy, cx = 4 + (c // 5) * 14, 3 + (c % 5) * 5
        x[i, cy : cy + 5, cx : cx + 4] += 0.8
    x = np.clip(x, 0, 1)
    return (x, y)


class TestTraining:
    def test_linear_loss_decreases(self):
        xy = synthetic_blob_dataset()
        params, curve = train.sgd_train(
            "linear", xy, steps=120, batch=50, lr=0.3, log_every=20
        )
        assert curve[-1][1] < curve[0][1] * 0.5, curve

    def test_linear_learns_blobs(self):
        xy = synthetic_blob_dataset(600)
        params, _ = train.sgd_train(
            "linear", xy, steps=200, batch=50, lr=0.3, log_every=0
        )
        acc = train.evaluate("linear", params, synthetic_blob_dataset(200, seed=1))
        assert acc > 0.9, f"acc {acc}"

    def test_qat_quant_flag_respected(self):
        xy = synthetic_blob_dataset(100)
        p1, _ = train.sgd_train("linear", xy, steps=5, batch=20, lr=0.1,
                                log_every=0, quant=False, seed=3)
        p2, _ = train.sgd_train("linear", xy, steps=5, batch=20, lr=0.1,
                                log_every=0, quant=True, input_bits=2, seed=3)
        # different quantization must produce different weights
        d = float(jnp.max(jnp.abs(p1["fc1.w"] - p2["fc1.w"])))
        assert d > 0


class TestExport:
    def test_tbnw_roundtrip(self, tmp_path):
        w = {
            "fc1.w": np.random.default_rng(0).normal(size=(10, 784)).astype(np.float32),
            "fc1.b": np.zeros(10, np.float32),
        }
        path = str(tmp_path / "w.bin")
        export.write_weights(path, w)
        back = export.read_weights(path)
        assert set(back) == set(w)
        np.testing.assert_array_equal(back["fc1.w"], w["fc1.w"])

    def test_tbnw_multidim(self, tmp_path):
        w = {"conv1.f": np.arange(5 * 5 * 1 * 32, dtype=np.float32).reshape(5, 5, 1, 32)}
        path = str(tmp_path / "c.bin")
        export.write_weights(path, w)
        back = export.read_weights(path)
        assert back["conv1.f"].shape == (5, 5, 1, 32)
        np.testing.assert_array_equal(back["conv1.f"], w["conv1.f"])

    def test_tbnw_header_bytes(self, tmp_path):
        path = str(tmp_path / "h.bin")
        export.write_weights(path, {"a": np.zeros(2, np.float32)})
        blob = open(path, "rb").read()
        assert blob[:4] == b"TBNW"
        assert blob[4:8] == (1).to_bytes(4, "little")


class TestAot:
    def test_reference_lowering_produces_hlo_text(self):
        params = M.init_linear(jax.random.PRNGKey(0))
        text = aot.lower_reference("linear", params, batch=2)
        assert "HloModule" in text
        # weights are baked in: only the image is a parameter
        assert text.count("parameter(1)") == 0

    def test_lut_lowering_contains_gathers(self):
        params = M.init_linear(jax.random.PRNGKey(1))
        text = aot.lower_lut_linear(params, batch=1, bits=3, m=4)
        assert "HloModule" in text
        # the kernel's row gathers lower to dynamic-slice/gather ops
        assert ("dynamic-slice" in text) or ("gather" in text)

    def test_cnn_lowering(self):
        params = M.init_cnn(jax.random.PRNGKey(2))
        text = aot.lower_reference("cnn", params, batch=1)
        assert "convolution" in text
