"""Layer-1 correctness: the Pallas LUT kernels against the pure-jnp
oracle — the CORE correctness signal of the compile path. Hypothesis
sweeps shapes, bit-widths and chunk sizes."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import lut_matmul as lk
from compile.kernels import ref


def rand_case(p, q, seed, scale=0.5):
    rng = np.random.default_rng(seed)
    w = (rng.normal(size=(p, q)) * scale).astype(np.float32)
    b = (rng.normal(size=(p,)) * 0.1).astype(np.float32)
    x = rng.uniform(size=(q,)).astype(np.float32)
    return w, b, x


class TestQuantizeKernel:
    def test_matches_ref_basic(self):
        x = np.linspace(0, 1, 97, dtype=np.float32)
        got = np.asarray(lk.quantize(x, 3))
        want = np.asarray(ref.quantize_ref(x, 3))
        np.testing.assert_array_equal(got, want)

    @given(
        bits=st.integers(1, 8),
        n=st.integers(1, 64),
        seed=st.integers(0, 2**16),
    )
    @settings(max_examples=25, deadline=None)
    def test_matches_ref_hypothesis(self, bits, n, seed):
        rng = np.random.default_rng(seed)
        x = rng.uniform(-0.2, 1.2, size=(n,)).astype(np.float32)  # incl. out-of-range
        got = np.asarray(lk.quantize(x, bits))
        want = np.asarray(ref.quantize_ref(x, bits))
        np.testing.assert_array_equal(got, want)

    def test_saturates(self):
        x = np.array([-1.0, 0.0, 0.999, 5.0], dtype=np.float32)
        got = np.asarray(lk.quantize(x, 4))
        assert got[0] == 0 and got[-1] == 15


class TestLutMatmulKernel:
    def test_matches_oracle_small(self):
        w, b, x = rand_case(5, 12, 0)
        want = np.asarray(ref.affine_quant_ref(w, b, x, 3))
        got = np.asarray(lk.lut_affine(w, b, x, bits=3, m=4))
        np.testing.assert_allclose(got, want, atol=1e-5)

    @given(
        p=st.integers(1, 16),
        k=st.integers(1, 8),
        m=st.sampled_from([1, 2, 3, 4]),
        bits=st.integers(1, 6),
        seed=st.integers(0, 2**16),
    )
    @settings(max_examples=30, deadline=None)
    def test_matches_oracle_hypothesis(self, p, k, m, bits, seed):
        q = k * m
        w, b, x = rand_case(p, q, seed)
        want = np.asarray(ref.affine_quant_ref(w, b, x, bits))
        got = np.asarray(lk.lut_affine(w, b, x, bits=bits, m=m))
        np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)

    def test_batched_matches_per_sample(self):
        w, b, _ = rand_case(6, 8, 3)
        rng = np.random.default_rng(4)
        xb = rng.uniform(size=(5, 8)).astype(np.float32)
        got = np.asarray(lk.lut_affine(w, b, xb, bits=4, m=2))
        for i in range(5):
            single = np.asarray(lk.lut_affine(w, b, xb[i], bits=4, m=2))
            np.testing.assert_allclose(got[i], single, atol=1e-5)

    def test_chunk_size_invariance(self):
        # the partition must not change the result (paper's linearity)
        w, b, x = rand_case(4, 12, 7)
        outs = [
            np.asarray(lk.lut_affine(w, b, x, bits=3, m=m)) for m in (1, 2, 3, 4, 6)
        ]
        for o in outs[1:]:
            np.testing.assert_allclose(o, outs[0], atol=1e-4)

    def test_zero_input_gives_bias(self):
        w, b, _ = rand_case(5, 8, 9)
        x = np.zeros(8, dtype=np.float32)
        got = np.asarray(lk.lut_affine(w, b, x, bits=3, m=2))
        np.testing.assert_allclose(got, b, atol=1e-6)

    def test_monotone_precision_improves_error(self):
        # higher input precision must not hurt agreement with the
        # unquantized affine
        w, b, x = rand_case(8, 16, 11)
        exact = np.asarray(ref.affine_ref(w, b, x))
        errs = []
        for bits in (1, 3, 6):
            got = np.asarray(lk.lut_affine(w, b, x, bits=bits, m=4))
            errs.append(np.max(np.abs(got - exact)))
        assert errs[2] <= errs[1] <= errs[0] + 1e-6, errs


class TestReferenceIdentities:
    """The oracle itself must satisfy the paper's linearity identities."""

    @given(
        seed=st.integers(0, 2**16),
        bits=st.integers(1, 8),
    )
    @settings(max_examples=20, deadline=None)
    def test_lut_ref_equals_quant_affine(self, seed, bits):
        w, b, x = rand_case(6, 12, seed)
        a = np.asarray(ref.lut_affine_ref(w, b, x, bits, 3))
        c = np.asarray(ref.affine_quant_ref(w, b, x, bits))
        np.testing.assert_allclose(a, c, atol=1e-4)

    def test_plane_indices_rebuild_codes(self):
        rng = np.random.default_rng(0)
        codes = rng.integers(0, 8, size=(12,)).astype(np.int32)
        idx = np.asarray(ref.plane_indices(codes, 1, 3))  # m=1: idx == bit
        rebuilt = sum((idx[j] << j) for j in range(3))
        np.testing.assert_array_equal(rebuilt, codes)

    def test_tables_first_row_zero(self):
        w = np.ones((3, 4), dtype=np.float32)
        tables, _ = ref.build_tables(w, np.zeros(3, np.float32), 2)
        np.testing.assert_array_equal(np.asarray(tables)[:, 0, :], 0.0)

    def test_tables_superposition(self):
        # row(a|b) = row(a) + row(b) for disjoint bit sets
        rng = np.random.default_rng(5)
        w = rng.normal(size=(4, 6)).astype(np.float32)
        tables, _ = ref.build_tables(w, np.zeros(4, np.float32), 3)
        t = np.asarray(tables)
        for c in range(t.shape[0]):
            np.testing.assert_allclose(t[c, 0b101], t[c, 0b100] + t[c, 0b001], atol=1e-6)
            np.testing.assert_allclose(t[c, 0b111], t[c, 0b110] + t[c, 0b001], atol=1e-6)
