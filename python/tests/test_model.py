"""Layer-2 model checks: shapes, quantization insertion, gradient flow,
and the LUT-path forward agreeing with the quantized reference."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


@pytest.fixture(scope="module")
def keys():
    return jax.random.split(jax.random.PRNGKey(42), 4)


class TestShapes:
    def test_linear(self, keys):
        p = M.init_linear(keys[0])
        x = jnp.zeros((7, 784))
        assert M.forward_linear(p, x).shape == (7, 10)

    def test_mlp(self, keys):
        p = M.init_mlp(keys[1])
        x = jnp.zeros((3, 784))
        assert M.forward_mlp(p, x, quant=True).shape == (3, 10)

    def test_cnn(self, keys):
        p = M.init_cnn(keys[2])
        x = jnp.zeros((2, 28, 28, 1))
        assert M.forward_cnn(p, x, quant=True).shape == (2, 10)

    def test_cnn_accepts_flat_input(self, keys):
        p = M.init_cnn(keys[2])
        x = jnp.zeros((2, 784))
        assert M.forward_cnn(p, x).shape == (2, 10)

    def test_param_shapes_match_rust_expectations(self, keys):
        p = M.init_mlp(keys[1])
        assert p["fc1.w"].shape == (1024, 784)
        assert p["fc2.w"].shape == (512, 1024)
        assert p["fc3.w"].shape == (10, 512)
        c = M.init_cnn(keys[2])
        assert c["conv1.f"].shape == (5, 5, 1, 32)
        assert c["conv2.f"].shape == (5, 5, 32, 64)
        assert c["fc1.w"].shape == (1024, 3136)


class TestQuantization:
    def test_fake_quant_fixed_levels(self):
        x = jnp.linspace(0, 1, 100)
        q = M.fake_quant_fixed(x, 3)
        assert len(np.unique(np.asarray(q).round(6))) <= 8

    def test_fake_quant_fixed_gradient_is_straight_through(self):
        g = jax.grad(lambda x: jnp.sum(M.fake_quant_fixed(x, 3)))(jnp.ones(5) * 0.4)
        np.testing.assert_allclose(np.asarray(g), 1.0)

    def test_fake_quant_f16_error_bound(self):
        x = jnp.asarray(np.random.default_rng(0).uniform(0.1, 8.0, 100).astype(np.float32))
        q = M.fake_quant_f16(x)
        rel = np.max(np.abs(np.asarray(q - x)) / np.asarray(x))
        assert rel <= 2.0**-11

    def test_quant_changes_forward(self, keys):
        p = M.init_linear(keys[0])
        x = jnp.asarray(
            np.random.default_rng(1).uniform(size=(4, 784)).astype(np.float32)
        )
        full = M.forward_linear(p, x, quant=False)
        q3 = M.forward_linear(p, x, quant=True, input_bits=3)
        d = np.max(np.abs(np.asarray(full - q3)))
        assert 0 < d < 1.0


class TestGradients:
    def test_mlp_grads_nonzero_everywhere(self, keys):
        p = M.init_mlp(keys[1])
        x = jnp.asarray(
            np.random.default_rng(2).uniform(size=(8, 784)).astype(np.float32)
        )
        y = jnp.arange(8) % 10

        def loss(p):
            return M.cross_entropy(M.forward_mlp(p, x, quant=True), y)

        g = jax.grad(loss)(p)
        for name, grad in g.items():
            assert float(jnp.sum(jnp.abs(grad))) > 0, f"dead gradient for {name}"

    def test_cnn_grads_flow_through_quant(self, keys):
        p = M.init_cnn(keys[2])
        x = jnp.asarray(
            np.random.default_rng(3).uniform(size=(2, 28, 28, 1)).astype(np.float32)
        )
        y = jnp.array([1, 7])

        def loss(p):
            return M.cross_entropy(M.forward_cnn(p, x, quant=True), y)

        g = jax.grad(loss)(p)
        assert float(jnp.sum(jnp.abs(g["conv1.f"]))) > 0


class TestLutForward:
    def test_linear_lut_matches_quant_reference(self, keys):
        p = M.init_linear(keys[0])
        x = jnp.asarray(
            np.random.default_rng(4).uniform(size=(3, 784)).astype(np.float32)
        )
        lut = M.forward_linear_lut(p, x, bits=3, m=4)
        want = M.forward_linear(p, M.fake_quant_fixed(x, 3), quant=False)
        np.testing.assert_allclose(np.asarray(lut), np.asarray(want), atol=1e-3)

    def test_linear_lut_classifies_like_reference(self, keys):
        p = M.init_linear(keys[0])
        x = jnp.asarray(
            np.random.default_rng(5).uniform(size=(16, 784)).astype(np.float32)
        )
        a = np.argmax(np.asarray(M.forward_linear_lut(p, x, bits=3, m=4)), axis=-1)
        b = np.argmax(
            np.asarray(M.forward_linear(p, M.fake_quant_fixed(x, 3))), axis=-1
        )
        assert (a == b).mean() >= 15 / 16
