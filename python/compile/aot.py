"""AOT export (Layer 2 -> artifacts): lowers the reference forward
passes — and the Pallas LUT-kernel graph — to HLO **text** for the Rust
PJRT runtime.

HLO text (not `.serialize()`): jax >= 0.5 emits HloModuleProto with
64-bit instruction ids which xla_extension 0.5.1 rejects; the text
parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md and rust/src/runtime/).

Usage:
    python -m compile.aot --weights ../artifacts/weights_linear.bin \
        --arch linear --out-dir ../artifacts [--batches 1,32]
"""

from __future__ import annotations

import argparse
import functools
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import export
from . import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def lower_reference(arch: str, params, batch: int) -> str:
    """Reference forward with weights baked in as constants: the Rust
    side feeds only the image batch."""
    forward = M.FORWARDS[arch]
    const_params = {k: jnp.asarray(v) for k, v in params.items()}

    def fn(x):
        return (forward(const_params, x, quant=False),)

    shape = M.input_shape(arch, batch)
    # rust runtime always feeds [batch, features]
    flat_shape = (batch, int(np.prod(shape[1:])))
    spec = jax.ShapeDtypeStruct(flat_shape, jnp.float32)

    def fn_flat(x):
        return fn(x.reshape(shape) if arch == "cnn" else x)

    return to_hlo_text(jax.jit(fn_flat).lower(spec))


def lower_lut_linear(params, batch: int, *, bits: int = 3, m: int = 4) -> str:
    """The LUT-path linear forward (contains the Pallas kernel, lowered
    via interpret=True into plain HLO ops) — proof that Layer 1 lowers
    into HLO the Rust runtime can execute."""
    const_params = {k: jnp.asarray(v) for k, v in params.items()}

    def fn(x):
        return (M.forward_linear_lut(const_params, x, bits=bits, m=m),)

    spec = jax.ShapeDtypeStruct((batch, 784), jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(spec))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=["linear", "mlp", "cnn"], required=True)
    ap.add_argument("--weights", required=True)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--batches", default="1,32")
    ap.add_argument("--lut", action="store_true",
                    help="also export the Pallas LUT graph (linear only)")
    args = ap.parse_args()

    params = export.read_weights(args.weights)
    os.makedirs(args.out_dir, exist_ok=True)
    for b in [int(x) for x in args.batches.split(",") if x]:
        text = lower_reference(args.arch, params, b)
        path = os.path.join(args.out_dir, f"{args.arch}_ref_b{b}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")
    if args.lut and args.arch == "linear":
        text = lower_lut_linear(params, 1)
        path = os.path.join(args.out_dir, "linear_lut_b1.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")


if __name__ == "__main__":
    main()
