"""Pure-jnp oracles for the Pallas kernels.

Everything here is straight-line jax.numpy with no Pallas, serving as the
correctness reference (pytest compares kernel outputs against these).

The paper's construction (fixed-point bitplane LUT matmul):
  - input x in [0,1]^q quantized to n-bit codes;
  - q split into k chunks of m elements;
  - per chunk, a table of 2^m rows holding W restricted to the chunk,
    evaluated at the LSB-plane scale;
  - per bitplane j, the chunk's plane-j bits form the row index and the
    row is accumulated scaled by 2^j (a shift in hardware).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def quantize_ref(x, bits: int):
    """Fixed-point quantizer: codes = floor(x * 2^bits), saturating."""
    levels = 2**bits
    codes = jnp.floor(x * levels)
    return jnp.clip(codes, 0, levels - 1).astype(jnp.int32)


def dequantize_ref(codes, bits: int):
    return codes.astype(jnp.float32) / (2.0**bits)


def affine_ref(w, b, x):
    """Plain affine Wx + b; w: [p, q], x: [..., q]."""
    return x @ w.T + b


def affine_quant_ref(w, b, x, bits: int):
    """The semantics the LUT implementation must reproduce: affine on the
    quantized input."""
    return affine_ref(w, b, dequantize_ref(quantize_ref(x, bits), bits))


def build_tables(w, b, m: int):
    """Build bitplane LUT tables for a [p, q] weight matrix with chunk
    size m (q % m == 0 for the kernel path).

    Returns (tables [k, 2^m, p] float32, bias [p]) where
      tables[c, idx, :] = sum_{e: bit_e(idx)=1} w[:, c*m + e]
    at unit plane scale (caller applies 2^(j-bits)); the bias is added
    once by the caller.
    """
    w = np.asarray(w)
    p, q = w.shape
    assert q % m == 0, f"chunk {m} must divide q={q}"
    k = q // m
    rows = 1 << m
    tables = np.zeros((k, rows, p), dtype=np.float32)
    for c in range(k):
        for idx in range(rows):
            for e in range(m):
                if (idx >> e) & 1:
                    tables[c, idx] += w[:, c * m + e]
    return jnp.asarray(tables), jnp.asarray(np.asarray(b, dtype=np.float32))


def plane_indices(codes, m: int, bits: int):
    """Row indices per (plane, chunk): idx[j, c] = Σ_e bit_j(codes[c*m+e]) << e.

    codes: [..., q] int32 -> [..., bits, k] int32. This is pure bit
    routing — the part the paper's concluding remarks assign to custom
    wiring; on TPU it is integer shift/and/sum on the VPU.
    """
    q = codes.shape[-1]
    assert q % m == 0
    k = q // m
    j = jnp.arange(bits, dtype=jnp.int32).reshape((1,) * (codes.ndim - 1) + (bits, 1))
    planes = (codes[..., None, :] >> j) & 1  # [..., bits, q]
    chunked = planes.reshape(planes.shape[:-1] + (k, m))  # [..., bits, k, m]
    weights = 1 << jnp.arange(m, dtype=jnp.int32)  # [m]
    return jnp.sum(chunked * weights, axis=-1).astype(jnp.int32)  # [..., bits, k]


def lut_matmul_ref(tables, bias, idx, bits: int):
    """Oracle for the LUT matmul kernel.

    tables: [k, 2^m, p]; idx: [..., bits, k]; returns [..., p] =
      bias + Σ_j 2^(j-bits) Σ_c tables[c, idx[..., j, c], :]
    """
    k = tables.shape[0]
    gathered = tables[jnp.arange(k), idx]  # [..., bits, k, p]
    scales = (2.0 ** (jnp.arange(bits) - bits)).astype(jnp.float32)
    out = jnp.einsum("...jkp,j->...p", gathered, scales)
    return out + bias


def lut_affine_ref(w, b, x, bits: int, m: int):
    """End-to-end LUT affine: quantize -> indices -> table gathers.

    Must equal affine_quant_ref to float tolerance (the identity the
    whole paper rests on).
    """
    tables, bias = build_tables(w, b, m)
    codes = quantize_ref(x, bits)
    idx = plane_indices(codes, m, bits)
    return lut_matmul_ref(tables, bias, idx, bits)
