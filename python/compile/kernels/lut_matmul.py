"""Layer-1 Pallas kernels: the paper's multiplier-less affine hot-spot.

HARDWARE ADAPTATION (see DESIGN.md §Hardware-Adaptation): the paper
targets LUT memory arrays with bit-rerouting circuitry. On TPU we map

  * LUT table  -> a (2^m, p) block resident in VMEM (scratchpad);
  * bit routing -> VPU integer shift/and ops computing row indices;
  * row read + shift-add -> dynamic-slice gather + accumulate, where the
    2^j plane scaling is an f32 exponent increment (a shift in the
    hardware's fixed-point view — no MXU, no general multiplier).

The kernels run with interpret=True (CPU PJRT cannot execute Mosaic
custom-calls); structure, not wallclock, is what we optimise here. The
VMEM working set per grid step is one table block (2^m · p · 4 B) plus
one index row — the BlockSpec below expresses exactly that.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Pallas on this jax version requires interpret mode for CPU execution.
INTERPRET = True


def _quantize_kernel(x_ref, o_ref, *, bits: int):
    levels = 2**bits
    v = jnp.floor(x_ref[...] * levels)
    o_ref[...] = jnp.clip(v, 0, levels - 1).astype(jnp.int32)


def quantize(x, bits: int):
    """Pallas elementwise fixed-point quantizer: [..., q] f32 -> int32."""
    kernel = functools.partial(_quantize_kernel, bits=bits)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.int32),
        interpret=INTERPRET,
    )(x)


def _lut_matmul_kernel(tables_ref, idx_ref, bias_ref, o_ref, *, bits: int, k: int):
    """Grid over chunks c. Each step gathers this chunk's rows for all
    planes and accumulates. tables_ref block: [2^m, p] (this chunk's
    table in VMEM); idx_ref block: [bits, 1]; o_ref: [p] accumulator.
    """
    c = pl.program_id(0)

    @pl.when(c == 0)
    def _init():
        o_ref[...] = bias_ref[...]

    table = tables_ref[0]  # [rows, p] — the VMEM-resident chunk table
    idx = idx_ref[...]  # [bits, 1]
    acc = jnp.zeros_like(o_ref)
    for j in range(bits):  # planes: static unroll (n is small: 1..8)
        row = table[idx[j, 0]]  # dynamic row gather
        # 2^(j-bits) plane scaling: exponent increment (hardware shift)
        acc = acc + row * (2.0 ** (j - bits))
    o_ref[...] += acc


def lut_matmul(tables, idx, bias, *, bits: int):
    """Multiplier-less affine via bitplane LUT gathers.

    tables: [k, 2^m, p] f32 — chunk tables (built at compile time from W)
    idx:    [bits, k] int32 — plane-j row index per chunk
    bias:   [p] f32
    returns [p] f32 == bias + Σ_j 2^(j-bits) Σ_c tables[c, idx[j, c]]
    """
    k, rows, p = tables.shape
    kernel = functools.partial(_lut_matmul_kernel, bits=bits, k=k)
    return pl.pallas_call(
        kernel,
        grid=(k,),
        in_specs=[
            # one chunk's table per grid step — the HBM->VMEM schedule
            pl.BlockSpec((1, rows, p), lambda c: (c, 0, 0)),
            pl.BlockSpec((bits, 1), lambda c: (0, c)),
            pl.BlockSpec((p,), lambda c: (0,)),
        ],
        out_specs=pl.BlockSpec((p,), lambda c: (0,)),
        out_shape=jax.ShapeDtypeStruct((p,), jnp.float32),
        interpret=INTERPRET,
    )(tables, idx, bias)


def lut_matmul_batched(tables, idx, bias, *, bits: int):
    """Batched wrapper: idx [b, bits, k] -> [b, p] (vmap over the batch;
    tables and bias are broadcast — they stay resident)."""
    f = functools.partial(lut_matmul, bits=bits)
    return jax.vmap(lambda i: f(tables, i, bias))(idx)


def lut_affine(w, b, x, *, bits: int, m: int):
    """End-to-end LUT affine for a batch: quantize (Pallas) -> indices
    (VPU bit routing) -> LUT matmul (Pallas). Mirrors ref.lut_affine_ref.
    """
    from . import ref

    codes = quantize(x, bits)
    idx = ref.plane_indices(codes, m, bits)
    tables, bias = ref.build_tables(w, b, m)
    if x.ndim == 1:
        return lut_matmul(tables, idx, bias, bits=bits)
    return lut_matmul_batched(tables, idx, bias, bits=bits)
