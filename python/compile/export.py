"""TBNW weights export: the little-endian binary format read by
rust/src/nn/weights.rs (magic `TBNW`, version 1)."""

from __future__ import annotations

import struct

import numpy as np

MAGIC = b"TBNW"
VERSION = 1


def write_weights(path: str, weights: dict) -> None:
    """Write a {name: array} dict, sorted by name (matching the Rust
    BTreeMap ordering) as f32 row-major."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", VERSION))
        f.write(struct.pack("<I", len(weights)))
        for name in sorted(weights):
            arr = np.ascontiguousarray(np.asarray(weights[name], dtype=np.float32))
            nb = name.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<Q", d))
            f.write(arr.tobytes())


def read_weights(path: str) -> dict:
    """Read back a TBNW file (round-trip validation in tests)."""
    out = {}
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC, "bad magic"
        (version,) = struct.unpack("<I", f.read(4))
        assert version == VERSION, f"bad version {version}"
        (count,) = struct.unpack("<I", f.read(4))
        for _ in range(count):
            (nlen,) = struct.unpack("<I", f.read(4))
            name = f.read(nlen).decode()
            (rank,) = struct.unpack("<I", f.read(4))
            shape = struct.unpack(f"<{rank}Q", f.read(8 * rank))
            n = int(np.prod(shape)) if rank else 1
            data = np.frombuffer(f.read(4 * n), dtype="<f4").reshape(shape)
            out[name] = data
    return out
