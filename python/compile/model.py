"""Layer-2 JAX models: the paper's three architectures with the
quantization ops it inserts "before the input to a CNN or dense linear
layer". Pure-jax pytrees (no flax); the forward functions are what
aot.py lowers to HLO text for the Rust PJRT runtime, and the LUT-path
forward calls the Layer-1 Pallas kernel so it lowers into the same HLO.

Weight orientation matches the Rust side: dense kernels are [p, q]
(output-major), conv filters are [fh, fw, cin, cout] (NHWC).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import lut_matmul as lk
from .kernels import ref

IMG = 28


# --------------------------------------------------------------------- #
# quantizers (straight-through estimator for QAT)
# --------------------------------------------------------------------- #
def fake_quant_fixed(x, bits: int):
    """Fixed-point fake-quant with straight-through gradients."""
    levels = 2.0**bits
    q = jnp.clip(jnp.floor(x * levels), 0, levels - 1) / levels
    return x + jax.lax.stop_gradient(q - x)


def fake_quant_f16(x):
    """binary16 fake-quant with straight-through gradients."""
    q = x.astype(jnp.float16).astype(jnp.float32)
    return x + jax.lax.stop_gradient(q - x)


# --------------------------------------------------------------------- #
# parameter initialisation
# --------------------------------------------------------------------- #
def init_linear(key):
    k1, _ = jax.random.split(key)
    return {
        "fc1.w": jax.random.normal(k1, (10, 784)) * (2.0 / 784) ** 0.5,
        "fc1.b": jnp.zeros((10,)),
    }


def init_mlp(key):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "fc1.w": jax.random.normal(k1, (1024, 784)) * (2.0 / 784) ** 0.5,
        "fc1.b": jnp.zeros((1024,)),
        "fc2.w": jax.random.normal(k2, (512, 1024)) * (2.0 / 1024) ** 0.5,
        "fc2.b": jnp.zeros((512,)),
        "fc3.w": jax.random.normal(k3, (10, 512)) * (2.0 / 512) ** 0.5,
        "fc3.b": jnp.zeros((10,)),
    }


def init_cnn(key):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "conv1.f": jax.random.normal(k1, (5, 5, 1, 32)) * (2.0 / 25) ** 0.5,
        "conv1.b": jnp.zeros((32,)),
        "conv2.f": jax.random.normal(k2, (5, 5, 32, 64)) * (2.0 / (25 * 32)) ** 0.5,
        "conv2.b": jnp.zeros((64,)),
        "fc1.w": jax.random.normal(k3, (1024, 3136)) * (2.0 / 3136) ** 0.5,
        "fc1.b": jnp.zeros((1024,)),
        "fc2.w": jax.random.normal(k4, (10, 1024)) * (2.0 / 1024) ** 0.5,
        "fc2.b": jnp.zeros((10,)),
    }


INITS = {"linear": init_linear, "mlp": init_mlp, "cnn": init_cnn}


# --------------------------------------------------------------------- #
# forward passes
# --------------------------------------------------------------------- #
def forward_linear(params, x, *, quant: bool = False, input_bits: int = 8):
    """x: [b, 784] -> logits [b, 10]."""
    if quant:
        x = fake_quant_fixed(x, input_bits)
    return x @ params["fc1.w"].T + params["fc1.b"]


def forward_mlp(params, x, *, quant: bool = False, input_bits: int = 8):
    if quant:
        x = fake_quant_fixed(x, input_bits)
    h = jax.nn.relu(x @ params["fc1.w"].T + params["fc1.b"])
    if quant:
        h = fake_quant_f16(h)
    h = jax.nn.relu(h @ params["fc2.w"].T + params["fc2.b"])
    if quant:
        h = fake_quant_f16(h)
    return h @ params["fc3.w"].T + params["fc3.b"]


def _conv_same(x, f, b):
    # x: [b, h, w, cin]; f: [fh, fw, cin, cout]
    out = jax.lax.conv_general_dilated(
        x,
        f,
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return out + b


def _maxpool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def forward_cnn(params, x, *, quant: bool = False, input_bits: int = 8):
    """x: [b, 28, 28, 1] (or [b, 784], reshaped) -> logits [b, 10]."""
    if x.ndim == 2:
        x = x.reshape(-1, IMG, IMG, 1)
    if quant:
        x = fake_quant_fixed(x, input_bits)
    h = jax.nn.relu(_conv_same(x, params["conv1.f"], params["conv1.b"]))
    h = _maxpool2(h)
    if quant:
        h = fake_quant_f16(h)
    h = jax.nn.relu(_conv_same(h, params["conv2.f"], params["conv2.b"]))
    h = _maxpool2(h)
    if quant:
        h = fake_quant_f16(h)
    h = h.reshape(h.shape[0], -1)  # [b, 3136] NHWC flatten (matches Rust)
    h = jax.nn.relu(h @ params["fc1.w"].T + params["fc1.b"])
    if quant:
        h = fake_quant_f16(h)
    return h @ params["fc2.w"].T + params["fc2.b"]


FORWARDS = {"linear": forward_linear, "mlp": forward_mlp, "cnn": forward_cnn}


def forward_linear_lut(params, x, *, bits: int = 3, m: int = 4):
    """The LUT-path linear forward: calls the Layer-1 Pallas kernel, so
    `jax.jit(...).lower()` of this function contains the kernel in the
    exported HLO. x: [b, 784] -> [b, 10]."""
    return lk.lut_affine(params["fc1.w"], params["fc1.b"], x, bits=bits, m=m)


# --------------------------------------------------------------------- #
# loss / metrics
# --------------------------------------------------------------------- #
def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(logp[jnp.arange(labels.shape[0]), labels])


def accuracy(forward, params, x, y, **kw):
    pred = jnp.argmax(forward(params, x, **kw), axis=-1)
    return jnp.mean((pred == y).astype(jnp.float32))


def input_shape(arch: str, batch: int):
    return (batch, 784) if arch in ("linear", "mlp") else (batch, IMG, IMG, 1)
