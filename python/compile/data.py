"""IDX dataset loader for the JAX training path.

Reads the same IDX files the Rust side generates (`tablenet gen-data`),
so both languages train/evaluate on bit-identical corpora.
"""

from __future__ import annotations

import os
import struct

import numpy as np


def _read_u32(f):
    return struct.unpack(">I", f.read(4))[0]


def load_images(path: str) -> np.ndarray:
    with open(path, "rb") as f:
        magic = _read_u32(f)
        assert magic == 0x0803, f"bad image magic {magic:#x} in {path}"
        n, rows, cols = _read_u32(f), _read_u32(f), _read_u32(f)
        data = np.frombuffer(f.read(n * rows * cols), dtype=np.uint8)
    return data.reshape(n, rows, cols)


def load_labels(path: str) -> np.ndarray:
    with open(path, "rb") as f:
        magic = _read_u32(f)
        assert magic == 0x0801, f"bad label magic {magic:#x} in {path}"
        n = _read_u32(f)
        data = np.frombuffer(f.read(n), dtype=np.uint8)
    return data.astype(np.int32)


def load_dataset(data_dir: str, kind: str = "digits"):
    """Returns ((train_x, train_y), (test_x, test_y)); x in [0,1] f32
    of shape [n, 28, 28]."""
    prefix = "fashion-" if kind in ("fashion", "fashion-mnist") else ""
    tr_x = load_images(os.path.join(data_dir, f"{prefix}train-images-idx3-ubyte"))
    tr_y = load_labels(os.path.join(data_dir, f"{prefix}train-labels-idx1-ubyte"))
    te_x = load_images(os.path.join(data_dir, f"{prefix}t10k-images-idx3-ubyte"))
    te_y = load_labels(os.path.join(data_dir, f"{prefix}t10k-labels-idx1-ubyte"))
    to_f = lambda a: (a.astype(np.float32) / 255.0)
    return (to_f(tr_x), tr_y), (to_f(te_x), te_y)
